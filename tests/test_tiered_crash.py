"""Crash-injection matrix over every fsync/rename boundary.

Every durable storage path (block-run write, static-layout publish,
manifest publish, sliced-run shipping) announces its boundaries through
:mod:`repro.core.faults`.  Each test here first counts the boundaries one
clean pass crosses, then replays the operation once per boundary with a
hook that raises :class:`InjectedCrash` at exactly that point — a
simulated ``kill -9`` — and checks the recovery contract:

* reopening the store lands on the **latest-good** state (either the
  pre-op state or the fully published post-op state, never a torn one),
* **orphan** run directories from the aborted op are GC'd at open,
* reads after recovery are **bit-identical** to a single-index oracle
  holding the same committed transactions.
"""

import os
import shutil

import numpy as np
import pytest

from repro.core import (DynamicIndex, Warren, index_document, score_bm25,
                        write_static)
from repro.core.faults import InjectedCrash, set_hook
from repro.core.static import StaticIndex
from repro.tiered import (LeveledPolicy, StaticWarren, TieredStore,
                          split_demoted)

VOCAB = ["school", "education", "student", "government", "law", "state",
         "stock", "money", "business", "vibration", "conductor", "wind"]


@pytest.fixture(autouse=True)
def _always_clear_hook():
    yield
    set_hook(None)


def _text(n: int) -> str:
    return " ".join(VOCAB[(n * 7 + i * (1 + n % 5)) % len(VOCAB)]
                    for i in range(3 + n % 6))


def _ingest(warren, ids):
    with warren:
        warren.transaction()
        for n in ids:
            index_document(warren, _text(n), docid=f"d{n}")
        warren.commit()


def _erase(warren, docid):
    with warren:
        lst = warren.annotations("docid:" + docid)
        assert len(lst) == 1
        warren.transaction()
        warren.erase(int(lst.starts[0]), int(lst.ends[0]))
        warren.commit()


def _view(warren, feature):
    """Address-free view of a feature's list: sorted (text, value)."""
    lst = warren.annotations(feature)
    out = []
    for i in range(len(lst)):
        out.append((warren.translate(int(lst.starts[i]), int(lst.ends[i])),
                    float(lst.values[i])))
    return sorted(out, key=lambda t: (t[0] or "", t[1]))


FEATURES = (":", "docid:d5", "docid:d21", "docid:d3", "docid:d17")
QUERIES = ("school education student", "government law state")


def _oracle(n=30, erased=("d3", "d17")):
    w = Warren(DynamicIndex())
    _ingest(w, range(n))
    for d in erased:
        _erase(w, d)
    return w


def _assert_oracle_parity(warren, oracle, queries=QUERIES):
    with warren, oracle:
        for f in FEATURES:
            assert _view(warren, f) == _view(oracle, f), f
        for q in queries:
            got = score_bm25(warren, q, k=10)
            ref = score_bm25(oracle, q, k=10)
            np.testing.assert_allclose([s for _, s in got],
                                       [s for _, s in ref], rtol=1e-9)


def _crash_at(k):
    state = {"n": 0}

    def hook(name):
        n = state["n"]
        state["n"] += 1
        if n == k:
            raise InjectedCrash(name, n)
    return hook


def _count_boundaries(op):
    """Run ``op`` once cleanly, recording every fault point it crosses."""
    names = []
    set_hook(names.append)
    try:
        op()
    finally:
        set_hook(None)
    return names


def _assert_no_orphans(store_dir, manifest):
    runs_dir = os.path.join(store_dir, "runs")
    if os.path.isdir(runs_dir):
        assert set(os.listdir(runs_dir)) == {i.name for i in manifest.runs}


# ------------------------------------------------------------------ #
# freeze: WAL -> block run -> manifest
# ------------------------------------------------------------------ #
def _seed_hot(path, n=30, erased=("d3", "d17")):
    store = TieredStore(path)
    w = store.warren()
    _ingest(w, range(n))
    for d in erased:
        _erase(w, d)
    store.close()


def test_freeze_crash_matrix(tmp_path):
    seed = str(tmp_path / "seed")
    _seed_hot(seed)
    oracle = _oracle()

    probe = str(tmp_path / "probe")
    shutil.copytree(seed, probe)
    st = TieredStore(probe)
    names = _count_boundaries(st.freeze)
    st.close()
    # the clean pass crosses every layer's boundary at least once
    for expected in ("run.blocks_written", "run.synced",
                     "static.pre_publish", "static.published",
                     "manifest.written", "manifest.published"):
        assert expected in names, names

    for k, name in enumerate(names):
        work = str(tmp_path / f"f{k}")
        shutil.copytree(seed, work)
        store = TieredStore(work)
        set_hook(_crash_at(k))
        with pytest.raises(InjectedCrash):
            store.freeze()
        set_hook(None)
        # abandon the in-memory store (simulated kill) and reopen from disk
        recovered = TieredStore(work)
        _assert_oracle_parity(recovered.warren(), oracle)
        _assert_no_orphans(work, recovered.manifest)
        # and the next freeze on the recovered store completes cleanly
        recovered.freeze()
        _assert_oracle_parity(recovered.warren(), oracle)
        recovered.close()


# ------------------------------------------------------------------ #
# leveled compaction: merged run -> manifest -> victim GC
# ------------------------------------------------------------------ #
def _seed_runs(path, n=30, erased=("d3", "d17"), batches=3):
    store = TieredStore(path)
    w = store.warren()
    per = n // batches
    for b in range(batches):
        _ingest(w, range(b * per, (b + 1) * per))
        store.freeze()
    for d in erased:
        _erase(w, d)
    store.freeze()
    store.close()


def test_compact_level_crash_matrix(tmp_path):
    seed = str(tmp_path / "seed")
    _seed_runs(seed)
    oracle = _oracle()
    policy = LeveledPolicy(l0_trigger=2)

    probe = str(tmp_path / "probe")
    shutil.copytree(seed, probe)
    st = TieredStore(probe)
    assert st.n_runs >= 2
    names = _count_boundaries(lambda: st.compact_level(policy))
    st.close()
    assert "manifest.published" in names

    for k in range(len(names)):
        work = str(tmp_path / f"c{k}")
        shutil.copytree(seed, work)
        store = TieredStore(work)
        set_hook(_crash_at(k))
        with pytest.raises(InjectedCrash):
            store.compact_level(policy)
        set_hook(None)
        recovered = TieredStore(work)
        _assert_oracle_parity(recovered.warren(), oracle)
        _assert_no_orphans(work, recovered.manifest)
        # recovery is not just readable — the same compaction then lands,
        # unless the crash hit AFTER the manifest publish (the commit
        # point), in which case the merge is already durable and the
        # retry is rightly a no-op
        committed = any(i.level >= 1 for i in recovered.manifest.runs)
        info = recovered.compact_level(policy)
        if committed:
            assert info is None
        else:
            assert info is not None and info.level == 1
        _assert_oracle_parity(recovered.warren(), oracle)
        _assert_no_orphans(work, recovered.manifest)
        recovered.close()


# ------------------------------------------------------------------ #
# sliced cold split: source never touched until both sides durable
# ------------------------------------------------------------------ #
def test_split_demoted_crash_matrix(tmp_path):
    seed = str(tmp_path / "seed")
    _seed_runs(seed, batches=3)
    oracle = _oracle()

    with StaticWarren(seed) as sw:
        docs = sw.annotations(":")
        pivot = int(sorted(int(s) for s in docs.starts)[len(docs) // 2])

    def run_split(src, keep, moved):
        return split_demoted(src, keep, moved, pivot)

    probe = str(tmp_path / "probe")
    shutil.copytree(seed, probe)
    names = _count_boundaries(lambda: run_split(
        probe, str(tmp_path / "pk"), str(tmp_path / "pm")))
    assert "split.shipped" in names

    def union_view(keep, moved, feature):
        with StaticWarren(keep) as a, StaticWarren(moved) as b:
            return sorted(_view(a, feature) + _view(b, feature))

    for k in range(len(names)):
        keep = str(tmp_path / f"k{k}")
        moved = str(tmp_path / f"m{k}")
        set_hook(_crash_at(k))
        with pytest.raises(InjectedCrash):
            run_split(seed, keep, moved)
        set_hook(None)
        # the SOURCE is latest-good and bit-identical: never touched
        with StaticWarren(seed) as sw, oracle:
            for f in FEATURES:
                assert _view(sw, f) == _view(oracle, f), f
        # partial side dirs are the caller's to discard; after discarding,
        # the same split completes and the union matches the oracle
        shutil.rmtree(keep, ignore_errors=True)
        shutil.rmtree(moved, ignore_errors=True)
        run_split(seed, keep, moved)
        with oracle:
            want = {f: _view(oracle, f) for f in FEATURES}
        for f in FEATURES:
            assert union_view(keep, moved, f) == want[f], f


# ------------------------------------------------------------------ #
# static overwrite: the .old rename dance keeps one good layout
# ------------------------------------------------------------------ #
def test_write_static_overwrite_crash_keeps_a_good_layout(tmp_path):
    idx_old = DynamicIndex()
    w_old = Warren(idx_old)
    _ingest(w_old, range(5))
    idx_new = DynamicIndex()
    w_new = Warren(idx_new)
    _ingest(w_new, range(9))

    d = str(tmp_path / "layout")
    write_static(idx_old, d)
    names = _count_boundaries(
        lambda: write_static(idx_new, str(tmp_path / "probe")))

    for k in range(len(names)):
        work = str(tmp_path / f"w{k}")
        shutil.copytree(d, work)
        set_hook(_crash_at(k))
        with pytest.raises(InjectedCrash):
            write_static(idx_new, work)
        set_hook(None)
        si = StaticIndex(work)          # always opens: old or new, not torn
        n = len(si.annotations(":"))
        assert n in (5, 9), n
        si.close()

"""Optimized (beyond-paper) compute paths == baseline paths numerically."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import (causal_gqa_attention,
                                 chunked_causal_gqa_attention)
from repro.models import recsys as R
from repro.data import synth


@pytest.mark.parametrize("s,qc,kc", [(64, 16, 16), (128, 32, 64),
                                     (96, 32, 32)])
def test_chunked_attention_matches_full(s, qc, kc):
    rng = np.random.default_rng(s)
    b, hkv, g, d = 2, 2, 3, 16
    q = jnp.asarray(rng.standard_normal((b, s, hkv, g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    full = causal_gqa_attention(q, k, v)
    chunked = chunked_causal_gqa_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=2e-5, atol=2e-5)


def test_chunked_twotower_loss_matches_full():
    cfg = R.TwoTowerConfig(n_users=500, n_items=400, embed_dim=16,
                           tower_mlp=(32, 16))
    cfg_chunked = dataclasses.replace(cfg, loss_chunk=16)
    params = R.twotower_init(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             synth.twotower_batch(0, 64, cfg.n_users, cfg.n_items, 8).items()}
    full = R.twotower_loss(params, cfg, batch)
    chunked = R.twotower_loss(params, cfg_chunked, batch)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-5)
    # gradients agree too (the loss drives training)
    g1 = jax.grad(lambda p: R.twotower_loss(p, cfg, batch))(params)
    g2 = jax.grad(lambda p: R.twotower_loss(p, cfg_chunked, batch))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)


def test_chunked_attention_in_model():
    """End-to-end: transformer forward with chunking == without."""
    from repro.models import transformer as T
    cfg = T.TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                              n_kv_heads=2, head_dim=8, d_ff=64, vocab=128,
                              dtype="float32", remat=False)
    cfg_c = dataclasses.replace(cfg, attn_chunk_q=16, attn_chunk_kv=32)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 128, (2, 64)),
                       jnp.int32)
    np.testing.assert_allclose(np.asarray(T.forward(params, toks, cfg)),
                               np.asarray(T.forward(params, toks, cfg_c)),
                               rtol=2e-4, atol=2e-4)

"""repro.obs: metric primitives, registry, tracing, bench emission.

Covers the concurrency contract (16-thread hammers with exact totals),
trace-context propagation across the ScatterGather pool, the span-tree
acceptance path through the native sharded server, disabled-mode no-ops,
ScatterTimings windowing, and the BENCH_* schema round-trip."""

import json
import math
import threading

import pytest

from repro import obs
from repro.obs import (Counter, Gauge, Histogram, JsonlSink, MetricsRegistry,
                       Tracer, sanitize)
from repro.obs import bench as obs_bench


@pytest.fixture(autouse=True)
def _clean_global_obs():
    """Tests share the process-global registry/tracer: start clean,
    leave enabled for whoever runs next."""
    obs.enable()
    obs.registry().reset()
    obs.tracer().reset()
    obs.tracer().set_slow_dump(None, None)
    yield
    obs.enable()
    obs.tracer().set_slow_dump(None, None)


# --------------------------------------------------------------------- #
# primitives                                                            #
# --------------------------------------------------------------------- #

def test_histogram_percentiles_uniform():
    h = Histogram()
    for v in range(1, 1001):
        h.observe(float(v))
    # log buckets at 20/decade => ~12% relative resolution
    assert h.percentile(0.5) == pytest.approx(500, rel=0.15)
    assert h.percentile(0.95) == pytest.approx(950, rel=0.15)
    assert h.percentile(0.99) == pytest.approx(990, rel=0.15)
    snap = h.snapshot()
    assert snap["count"] == 1000
    assert snap["min"] == 1.0 and snap["max"] == 1000.0
    assert snap["p50"] <= snap["p95"] <= snap["p99"] <= snap["max"]


def test_histogram_empty_and_clamping():
    h = Histogram()
    assert math.isnan(h.percentile(0.5))
    h.observe(7.0)
    # single sample: every percentile must clamp to the one observation
    assert h.percentile(0.5) == 7.0
    assert h.percentile(0.99) == 7.0
    h.observe(0.0)       # underflow bucket (v <= lo)
    assert h.count == 2
    h.reset()
    assert h.count == 0 and math.isnan(h.percentile(0.5))


def test_counter_hammer_16_threads():
    c = Counter()
    n, per = 16, 5000

    def worker():
        for _ in range(per):
            c.inc()

    ts = [threading.Thread(target=worker) for _ in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n * per


def test_histogram_hammer_16_threads():
    h = Histogram()
    n, per = 16, 2000

    def worker(tid):
        for i in range(per):
            h.observe(1.0 + (tid * per + i) % 100)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    snap = h.snapshot()
    assert snap["count"] == n * per
    assert snap["min"] >= 1.0 and snap["max"] <= 100.0


def test_registry_get_or_create_hammer():
    reg = MetricsRegistry()
    n, per = 16, 1000

    def worker(tid):
        for _ in range(per):
            # same (name, labels) from every thread -> one series
            reg.counter("hammer_total", group=tid % 4).inc()

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    snap = reg.snapshot()["hammer_total"]
    assert len(snap["series"]) == 4
    assert sum(s["value"] for s in snap["series"]) == n * per


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x_total").inc()
    with pytest.raises(ValueError, match="counter"):
        reg.gauge("x_total")


def test_disabled_mode_is_a_noop():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("c")
    h = reg.histogram("h")
    g = reg.gauge("g")
    c.inc(10)
    h.observe(5.0)
    g.set(3.0)
    assert c.value == 0 and h.count == 0 and g.value == 0.0
    reg.enable()
    c.inc()
    assert c.value == 1


def test_prometheus_and_jsonl_exports(tmp_path):
    reg = MetricsRegistry()
    reg.counter("reads_total", "reads", group=0).inc(3)
    reg.histogram("lat_ms", "latency", site="s").observe(2.5)
    reg.gauge("depth").set(float("nan"))     # must not break JSON export
    text = reg.to_prometheus()
    assert 'reads_total{group="0"} 3' in text
    assert 'lat_ms_bucket{le="+Inf",site="s"} 1' in text
    assert 'lat_ms_sum{site="s"} 2.5' in text
    assert 'lat_ms_count{site="s"} 1' in text
    p = tmp_path / "m.jsonl"
    rec = JsonlSink(str(p)).write(reg)
    parsed = json.loads(p.read_text())       # strictly valid JSON
    assert parsed["metrics"]["reads_total"]["series"][0]["value"] == 3
    assert parsed["metrics"]["depth"]["series"][0]["value"] is None
    assert rec["metrics"]["lat_ms"]["series"][0]["count"] == 1


def test_sanitize_nonfinite():
    assert sanitize({"a": float("inf"), "b": [float("nan"), 1.5]}) == \
        {"a": None, "b": [None, 1.5]}


# --------------------------------------------------------------------- #
# tracing                                                               #
# --------------------------------------------------------------------- #

def test_span_nesting_and_tree():
    tr = Tracer()
    with tr.span("root", req=1):
        with tr.span("child_a"):
            with tr.span("leaf"):
                pass
        with tr.span("child_b"):
            pass
    t = tr.last_trace("root")
    assert t is not None
    tree = t.tree()
    assert tree["name"] == "root" and tree["labels"] == {"req": 1}
    assert [c["name"] for c in tree["children"]] == ["child_a", "child_b"]
    assert tree["children"][0]["children"][0]["name"] == "leaf"
    assert tree["duration_ms"] is not None and tree["duration_ms"] >= 0


def test_span_error_flag_propagates_exception():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("root"):
            with tr.span("boom"):
                raise RuntimeError("x")
    tree = tr.last_trace("root").tree()
    assert tree["error"] is True
    assert tree["children"][0]["error"] is True


def test_disabled_tracer_returns_shared_null():
    tr = Tracer(enabled=False)
    a, b = tr.span("x"), tr.span("y", k=1)
    assert a is b                      # shared no-op, no allocation
    with a:
        pass
    assert tr.traces() == []


def test_trace_propagation_across_scattergather():
    from repro.dist.parallel import ScatterGather
    tr = obs.tracer()
    with ScatterGather(workers=4) as sg:
        with obs.span("fanout.root"):
            sg.map(_traced_work, list(range(6)))
    t = tr.last_trace("fanout.root")
    assert t is not None
    tree = t.tree()
    kids = [c for c in tree["children"] if c["name"] == "work"]
    # every worker-side span parented under the submitting context's root
    assert sorted(c["labels"]["group"] for c in kids) == list(range(6))


def _traced_work(g):
    with obs.span("work", group=g):
        return g


def test_slow_trace_dump(tmp_path):
    tr = Tracer()
    p = tmp_path / "slow.jsonl"
    tr.set_slow_dump(0.0, str(p))          # everything is "slow"
    with tr.span("req"):
        with tr.span("inner"):
            pass
    assert tr.n_slow_dumped == 1
    rec = json.loads(p.read_text())
    assert rec["root"] == "req"
    assert [s["name"] for s in rec["spans"]] == ["req", "inner"]


# --------------------------------------------------------------------- #
# ScatterTimings windowing (the lifetime-average fix)                   #
# --------------------------------------------------------------------- #

def test_scatter_timings_window_and_epoch():
    from repro.dist.parallel import ScatterTimings
    st = ScatterTimings(site="test")
    st.add(scatter=0.010, score=0.020, merge=0.001)
    st.add(scatter=0.030, score=0.040, merge=0.002, queries=2)
    w = st.window()
    assert w["epoch"] == 0
    assert w["queries"] == 3
    assert w["scatter_s"] == pytest.approx(0.040)
    # window() reset the sums: a fresh window sees only new samples
    st.add(scatter=0.005)
    s = st.snapshot()
    assert s["epoch"] == 1
    assert s["queries"] == 1 and s["scatter_s"] == pytest.approx(0.005)
    # ...but the obs histograms keep the full trajectory
    h = obs.registry().histogram("serve_scatter_latency_ms", site="test")
    assert h.count == 3


# --------------------------------------------------------------------- #
# bench schema                                                          #
# --------------------------------------------------------------------- #

def test_bench_emit_validate_roundtrip(tmp_path):
    reg = MetricsRegistry()
    st_like = reg.histogram("serve_scatter_latency_ms", site="unit")
    for v in (1.0, 2.0, 3.0):
        st_like.observe(v)
    reg.histogram("serve_score_latency_ms", site="unit").observe(5.0)
    reg.histogram("serve_merge_latency_ms", site="unit").observe(0.5)
    p = tmp_path / "BENCH_serving.json"
    doc = obs_bench.emit(str(p), "serving",
                         extra={"bench": {"smoke": True}}, reg=reg)
    assert doc["schema"] == obs_bench.SCHEMA
    assert obs_bench.validate(str(p)) == []
    s = doc["metrics"]["serve_scatter_latency_ms"]["series"][0]
    assert s["count"] == 3 and {"p50", "p95", "p99"} <= set(s)
    assert obs_bench.main(["validate", str(p)]) == 0


def test_bench_refuses_invalid(tmp_path):
    # no serving histograms at all -> must refuse, must not write
    p = tmp_path / "BENCH_serving.json"
    with pytest.raises(ValueError, match="refusing"):
        obs_bench.emit(str(p), "serving", reg=MetricsRegistry())
    assert not p.exists()
    with pytest.raises(ValueError):
        obs_bench.emit(str(p), "nonsense-kind")
    # hand-broken doc fails validation
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": "other/v9", "kind": "serving",
                               "created": 0, "metrics": {}}))
    problems = obs_bench.validate(str(bad))
    assert problems
    assert obs_bench.main(["validate", str(bad)]) == 1


# --------------------------------------------------------------------- #
# instrumented subsystems                                               #
# --------------------------------------------------------------------- #

def test_txn_commit_metrics():
    from repro.core import DynamicIndex, Warren, index_document
    reg = obs.registry()
    with Warren(DynamicIndex()) as w:
        for i in range(3):
            w.transaction()
            index_document(w, f"doc number {i} words here", docid=f"d{i}")
            w.commit()
    h = reg.histogram("txn_commit_latency_ms")
    assert h.count >= 3
    assert h.sum > 0


def test_sharded_span_tree_and_metrics(tmp_path):
    """Acceptance: one search through the native sharded server yields the
    complete span tree and populates the serving metric families."""
    from repro.core import index_document
    from repro.dist.shard_router import ShardedWarren
    from repro.train.serve import RetrievalServer

    reg, tr = obs.registry(), obs.tracer()
    warren = ShardedWarren(n_shards=3, replicas=1,
                           static_dir=str(tmp_path), async_scatter=True)
    try:
        with warren:
            warren.transaction()
            for i in range(40):
                index_document(
                    warren,
                    f"school education student wind conductor item{i}",
                    docid=f"d{i}")
            warren.commit()
        server = RetrievalServer(warren, k=5)
        try:
            out = server.batcher.submit("school education").get(timeout=60)
        finally:
            server.close()
        assert len(out) > 0
    finally:
        warren.close()

    t = tr.last_trace("serve.batch")
    assert t is not None, "no serve.batch trace captured"
    names = set(t.names())
    assert {"serve.batch", "scatter", "replica_read",
            "device_score", "merge"} <= names
    tree = t.tree()
    scatters = [c for c in tree["children"] if c["name"] == "scatter"]
    assert sorted(s["labels"]["group"] for s in scatters) == [0, 1, 2]
    for s in scatters:
        assert any(k["name"] == "replica_read" for k in s["children"])

    # metric families the sweep must have fed
    snap = reg.snapshot()
    for fam in ("serve_scatter_latency_ms", "serve_score_latency_ms",
                "serve_merge_latency_ms", "scatter_latency_ms",
                "shard_read_total", "shard_write_total",
                "txn_quorum_wait_ms", "serve_batch_size",
                "serve_jit_recompile_total"):
        assert fam in snap, f"missing family {fam}"
        assert snap[fam]["series"], f"empty family {fam}"
    server_h = reg.histogram("serve_scatter_latency_ms", site="server")
    assert server_h.count >= 1


def test_obs_disable_silences_instrumentation(tmp_path):
    from repro.core import DynamicIndex, Warren, index_document
    obs.disable()
    before = obs.registry().histogram("txn_commit_latency_ms").count
    with Warren(DynamicIndex()) as w:
        w.transaction()
        index_document(w, "quiet doc", docid="q0")
        w.commit()
    assert obs.registry().histogram("txn_commit_latency_ms").count == before
    assert obs.tracer().span("x") is obs.tracer().span("y")


# --------------------------------------------------------------------- #
# Prometheus exposition conformance (format 0.0.4)                      #
# --------------------------------------------------------------------- #

def _parse_prometheus(text):
    """Minimal 0.0.4 parser: {(name, frozenset(labels)): value}."""
    out = {}
    for line in text.strip().split("\n"):
        if line.startswith("#"):
            continue
        metric, value = line.rsplit(" ", 1)
        if "{" in metric:
            name, rest = metric.split("{", 1)
            body = rest[:-1]
            labels = {}
            for part in body.split('",'):
                k, v = part.split("=", 1)
                labels[k] = v.strip('"')
            key = (name, frozenset(labels.items()))
        else:
            key = (metric, frozenset())
        out[key] = float(value) if value != "NaN" else math.nan
    return out


def test_prometheus_histogram_conformance():
    reg = MetricsRegistry()
    h = reg.histogram("lat_ms", "latency", site="a")
    for v in (0.5, 2.0, 2.0, 40.0, 1e9):     # includes an overflow sample
        h.observe(v)
    reg.histogram("lat_ms", "latency", site="b").observe(1.0)
    text = reg.to_prometheus()
    assert "# TYPE lat_ms histogram" in text

    # per-series: ascending le, non-decreasing cumulative counts,
    # terminal +Inf bucket equal to _count
    for site, count in (("a", 5), ("b", 1)):
        bounds, cums = [], []
        for line in text.split("\n"):
            if line.startswith("lat_ms_bucket") and f'site="{site}"' in line:
                metric, value = line.rsplit(" ", 1)
                le = metric.split('le="')[1].split('"')[0]
                bounds.append(math.inf if le == "+Inf" else float(le))
                cums.append(int(value))
        assert bounds == sorted(bounds)
        assert cums == sorted(cums)
        assert bounds[-1] == math.inf
        assert cums[-1] == count
        parsed = _parse_prometheus(text)
        assert parsed[("lat_ms_count",
                       frozenset({("site", site)}))] == count
    a_sum = _parse_prometheus(text)[("lat_ms_sum",
                                     frozenset({("site", "a")}))]
    assert a_sum == pytest.approx(0.5 + 2.0 + 2.0 + 40.0 + 1e9)


def test_prometheus_label_escaping():
    reg = MetricsRegistry()
    reg.counter("odd_total", "odd", path='a"b\\c\nd').inc()
    text = reg.to_prometheus()
    assert 'path="a\\"b\\\\c\\nd"' in text


# --------------------------------------------------------------------- #
# tracer hygiene: exception exits                                       #
# --------------------------------------------------------------------- #

def test_span_exception_sets_error_label_and_dumps(tmp_path):
    tr = Tracer()
    p = tmp_path / "slow.jsonl"
    tr.set_slow_dump(1e9, str(p))       # nothing is slow ...
    with pytest.raises(KeyError):
        with tr.span("req"):
            with tr.span("inner"):
                raise KeyError("boom")
    t = tr.last_trace("req")
    spans = {s.name: s for s in t.spans}
    assert spans["inner"].error and spans["req"].error
    assert spans["inner"].labels["error"] == "KeyError"
    assert t.duration_ms is not None    # trace still finished
    # ... but an errored trace is always dump-eligible
    assert tr.n_slow_dumped == 1
    rec = json.loads(p.read_text())
    assert rec["root"] == "req"


def test_span_error_label_does_not_clobber_user_label():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("req", error="custom"):
            raise ValueError("x")
    s = tr.last_trace("req").root
    assert s.error is True
    assert s.labels["error"] == "custom"     # setdefault semantics

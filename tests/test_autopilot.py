"""Autopilot control plane: exact decision sequences on a fake clock.

Tier-1 here covers the acceptance criteria of the autopilot issue, fully
deterministically — seeded workloads, SimClock, zero wall-clock sleeps:

  * canned scenarios resolve to EXACT decision sequences: a sustained-hot
    group splits, a cold group demotes then merges away, a diverged (or
    dead) replica re-syncs, and repair outranks reshaping;
  * hysteresis provably prevents flapping: a split is never reverted by
    a merge of the same group inside the cooldown window, any two
    actions are separated by the min-dwell, and attempted actions per
    sliding window are bounded — asserted on canned data AND as a
    property over arbitrary signal streams (hypothesis);
  * an aborted migration triggers capped exponential backoff — the
    controller keeps deciding (never wedges) and recovers when the
    mechanism heals;
  * the simulated day-in-the-life is bit-reproducible per seed, and the
    controller keeps sim p95 flat while a no-policy baseline degrades;
  * ``ScatterGather.resize`` swaps worker width under an in-flight
    fan-out without dropping results (the PR-4 static-sizing fix);
  * the real-warren closed loop: live signals + live actuator split a
    hot group, resurrect a dead replica, and demote an idle group, with
    served rankings bit-identical to a single index throughout.

Chaos variants (replica kills mid-controller-initiated split) live
behind the ``stress`` marker.
"""

import math
import threading

import pytest
from hypothesis import given, settings, strategies as st

from _sim import (RecordingActuator, decision_seq, run_scripted, sig,
                  tight_config)
from repro.dist.autopilot import (AntiEntropyPolicy, AutopilotConfig,
                                  ColdPolicy, Controller, Decision,
                                  GroupSignal, Hysteresis, HotSplitPolicy,
                                  RetryPolicy, ScriptedSignals,
                                  WarrenActuator, WarrenSignals)
from repro.dist.parallel import ScatterGather
from repro.dist.simharness import (DriftingWorkload, SimClock, SimCluster)


# ------------------------------------------------------------------ #
# exact decision sequences (canned scenarios, scripted signals)
# ------------------------------------------------------------------ #
def test_hot_split_exact_sequence():
    """p95 above threshold for sustain_ticks -> split; the streak resets
    and the cooldown holds, so the second split lands exactly when both
    have re-elapsed."""
    hot = [sig(0, docs=500, p95=80.0, reads=50), sig(1, docs=400, reads=40)]
    ctl, act = run_scripted([hot] * 10)
    assert decision_seq(ctl) == [
        (2, "split", 0, 2, "applied"),
        (7, "split", 0, 3, "applied"),
    ]
    assert act.calls == [("split", 0), ("split", 0)]


def test_skew_split_without_latency_signal():
    """Doc-count skew alone (p95 NaN, e.g. registry disabled) still
    triggers the split."""
    skew = [sig(0, docs=1500, reads=10), sig(1, docs=100, reads=10),
            sig(2, docs=110, reads=10)]
    ctl, _ = run_scripted([skew] * 4)
    assert decision_seq(ctl)[0] == (2, "split", 0, 3, "applied")


def test_cold_demote_then_merge_exact_sequence():
    """An idle group demotes at demote_after_ticks, then (still idle)
    merges into the smallest other active group at merge_after_ticks."""
    busy = [sig(0, docs=500, reads=30), sig(1, docs=300, reads=20)]
    before = [busy + [sig(2, docs=80, reads=0)]] * 3
    after = [busy + [sig(2, docs=80, reads=0, demoted=True)]] * 7
    ctl, act = run_scripted(before + after)
    assert decision_seq(ctl) == [
        (2, "demote", 2, None, "applied"),
        (8, "merge", 2, 1, "applied"),     # dest = smallest survivor
    ]
    assert act.calls == [("demote", 2), ("merge", 1, 2)]


def test_merge_respects_min_groups():
    """Two active groups with min_groups=2: the idle one demotes but is
    never merged away."""
    ticks = [[sig(0, docs=150, reads=30),
              sig(1, docs=80, reads=0, demoted=(t >= 3))]
             for t in range(12)]
    ctl, act = run_scripted(ticks)
    assert [d.kind for d in ctl.decisions] == ["demote"]


def test_resync_diverged_replica_exact_sequence():
    """A live replica whose seqnum trails the group max beyond the lag
    budget for sustain_ticks gets exactly one re-sync."""
    diverged = [sig(0, reads=10), sig(1, reads=10, seqs=(9, 5))]
    healed = [sig(0, reads=10), sig(1, reads=10, seqs=(9, 9))]
    ctl, act = run_scripted([diverged] * 2 + [healed] * 6)
    assert decision_seq(ctl) == [(1, "resync", 1, 1, "applied")]
    assert act.calls == [("resync", 1, 1)]


def test_resync_dead_replica():
    dead = [sig(0, reads=10, seqs=(9, 3), alive=(True, False))]
    ok = [sig(0, reads=10, seqs=(9, 9))]
    ctl, act = run_scripted([dead] * 2 + [ok] * 4)
    assert decision_seq(ctl) == [(1, "resync", 0, 1, "applied")]
    assert "dead" in ctl.decisions[0].reason


def test_repair_outranks_reshaping():
    """When a re-sync and a split are eligible on the same tick, the
    re-sync goes first (repair before reshaping)."""
    cfg = tight_config(anti_entropy=AntiEntropyPolicy(max_seq_lag=0,
                                                      sustain_ticks=3))
    both = [sig(0, docs=500, p95=80.0, reads=50),
            sig(1, reads=10, seqs=(9, 5))]
    ctl, _ = run_scripted([both] * 6, config=cfg)
    kinds = [(d.tick, d.kind) for d in ctl.decisions]
    assert kinds[0] == (2, "resync")
    assert kinds[1][1] == "split" and kinds[1][0] > 2


def test_decision_records_are_structured(tmp_path):
    """Decisions carry the full audit record and stream to the JSONL log."""
    import json

    log = tmp_path / "decisions.jsonl"
    hot = [sig(0, docs=500, p95=80.0, reads=50), sig(1, docs=400, reads=40)]
    clock = SimClock(start=100.0)
    ctl = Controller(ScriptedSignals([hot] * 3), RecordingActuator(next_gid=2),
                     config=tight_config(), clock=clock,
                     decision_log=str(log))
    for _ in range(3):
        ctl.tick()
        clock.advance()
    recs = [json.loads(line) for line in log.read_text().splitlines()]
    assert len(recs) == 1 and recs[0]["kind"] == "split"
    assert recs[0]["outcome"] == "applied" and recs[0]["t"] == 102.0
    assert "hot for 3 ticks" in recs[0]["reason"]
    assert ctl.decisions[0].to_record() == recs[0]


# ------------------------------------------------------------------ #
# hysteresis: the controller provably cannot flap
# ------------------------------------------------------------------ #
def test_split_never_reverted_by_merge_within_cooldown():
    """The canned flap bait: a group splits, then instantly goes idle
    with an aggressive merge policy.  The cooldown must hold the line."""
    cfg = tight_config(
        cold=ColdPolicy(demote_after_ticks=2, merge_after_ticks=3,
                        min_groups=1),
        hysteresis=Hysteresis(cooldown_ticks=6, min_dwell_ticks=1,
                              window_ticks=20, max_actions_per_window=8))
    hot = [sig(0, docs=500, p95=80.0, reads=50), sig(1, docs=400, reads=40)]
    idle = [sig(0, docs=250, reads=0), sig(1, docs=400, reads=40),
            sig(2, docs=250, reads=0)]
    ctl, _ = run_scripted([hot] * 3 + [idle] * 12, config=cfg)
    split = ctl.decisions[0]
    assert (split.tick, split.kind, split.outcome) == (2, "split", "applied")
    for d in ctl.decisions[1:]:
        if d.group in (0, split.target) or d.target in (0, split.target):
            assert d.tick > split.tick + cfg.hysteresis.cooldown_ticks, \
                f"{d.summary()} inside the cooldown window"


def test_min_dwell_separates_all_actions():
    """Even with every group permanently eligible, consecutive attempts
    are separated by more than min_dwell_ticks."""
    cfg = tight_config(
        anti_entropy=AntiEntropyPolicy(max_seq_lag=0, sustain_ticks=1),
        hysteresis=Hysteresis(cooldown_ticks=0, min_dwell_ticks=2,
                              window_ticks=50, max_actions_per_window=50))
    lag = [sig(g, reads=10, seqs=(9, 5)) for g in range(4)]
    ctl, _ = run_scripted([lag] * 12, config=cfg)
    ticks = [d.tick for d in ctl.decisions]
    assert ticks, "expected at least one action"
    assert all(b - a > 2 for a, b in zip(ticks, ticks[1:]))


def test_window_budget_bounds_total_actions():
    cfg = tight_config(
        anti_entropy=AntiEntropyPolicy(max_seq_lag=0, sustain_ticks=1),
        hysteresis=Hysteresis(cooldown_ticks=0, min_dwell_ticks=0,
                              window_ticks=10, max_actions_per_window=2))
    lag = [sig(g, reads=10, seqs=(9, 5)) for g in range(4)]
    ctl, _ = run_scripted([lag] * 40, config=cfg)
    ticks = [d.tick for d in ctl.decisions]
    assert len(ticks) >= 4                     # budget refills across windows
    for i, t in enumerate(ticks):
        inside = [u for u in ticks if t - 10 < u <= t]
        assert len(inside) <= 2, f"window ending at {t}: {inside}"


def _stream_strategy():
    """Arbitrary 3-group signal streams: any docs/latency/read pattern,
    replicas diverging and dying at random."""
    group = st.tuples(st.integers(0, 2000),            # docs
                      st.sampled_from([float("nan"), 5.0, 40.0, 80.0, 200.0]),
                      st.integers(0, 50),              # reads
                      st.integers(0, 9),               # trailing replica seq
                      st.booleans())                   # replica 1 alive
    return st.lists(st.tuples(group, group, group), min_size=10, max_size=40)


@given(_stream_strategy())
@settings(max_examples=30, deadline=None)
def test_property_hysteresis_invariants_hold_for_any_stream(stream):
    """For ARBITRARY signal sequences: the action budget per sliding
    window holds, min-dwell separates attempts, and no group is touched
    again within cooldown of an applied action on it."""
    cfg = tight_config(
        split=HotSplitPolicy(p95_hot_ms=50.0, skew_ratio=3.0, min_docs=10,
                             sustain_ticks=2, max_groups=16),
        cold=ColdPolicy(demote_after_ticks=2, merge_after_ticks=4,
                        min_groups=1),
        anti_entropy=AntiEntropyPolicy(max_seq_lag=0, sustain_ticks=2),
        hysteresis=Hysteresis(cooldown_ticks=5, min_dwell_ticks=1,
                              window_ticks=12, max_actions_per_window=3))
    ticks = [[GroupSignal(group=g, docs=docs, p95_ms=p95, reads=reads,
                          replica_seqs=(9, seq), alive=(True, alive))
              for g, (docs, p95, reads, seq, alive) in enumerate(tick)]
             for tick in stream]
    ctl, _ = run_scripted(ticks, config=cfg)

    attempts = [d.tick for d in ctl.decisions]
    hys = cfg.hysteresis
    for i, t in enumerate(attempts):
        inside = [u for u in attempts if t - hys.window_ticks < u <= t]
        assert len(inside) <= hys.max_actions_per_window
    assert all(b - a > hys.min_dwell_ticks
               for a, b in zip(attempts, attempts[1:]))

    def touched(d):
        out = {d.group}
        if d.kind in ("split", "merge") and d.target is not None:
            out.add(d.target)
        return out

    applied = [d for d in ctl.decisions if d.outcome == "applied"]
    for d in applied:
        for later in ctl.decisions:
            if d.tick < later.tick <= d.tick + hys.cooldown_ticks:
                assert not (touched(d) & touched(later)), \
                    f"{later.summary()} within cooldown of {d.summary()}"
                assert not (later.kind == "merge" and d.kind == "split"
                            and later.group in touched(d))


# ------------------------------------------------------------------ #
# aborted migrations: capped exponential backoff, never wedged
# ------------------------------------------------------------------ #
def test_backoff_on_aborted_split_is_capped_exponential():
    cfg = tight_config(
        hysteresis=Hysteresis(cooldown_ticks=1, min_dwell_ticks=0,
                              window_ticks=100, max_actions_per_window=100),
        retry=RetryPolicy(base_ticks=1, cap_ticks=8))
    hot = [sig(0, docs=500, p95=80.0, reads=50), sig(1, docs=400, reads=40)]
    act = RecordingActuator(next_gid=2, fail_kinds={"split"})
    ctl, _ = run_scripted([hot] * 40, config=cfg, actuator=act)
    assert all(d.outcome == "aborted" for d in ctl.decisions)
    assert len(ctl.decisions) >= 5             # kept retrying: never wedged
    gaps = [b.tick - a.tick for a, b in zip(ctl.decisions,
                                            ctl.decisions[1:])]
    assert gaps == sorted(gaps)                # monotone backoff
    assert gaps[0] <= 2 and max(gaps) <= cfg.retry.cap_ticks + 1
    assert gaps[-1] == cfg.retry.cap_ticks + 1  # capped, not unbounded


def test_backoff_recovers_when_mechanism_heals():
    cfg = tight_config(
        hysteresis=Hysteresis(cooldown_ticks=1, min_dwell_ticks=0,
                              window_ticks=100, max_actions_per_window=100))
    hot = [sig(0, docs=500, p95=80.0, reads=50), sig(1, docs=400, reads=40)]
    act = RecordingActuator(next_gid=2, fail_kinds={"split"}, fail_budget=2)
    ctl, _ = run_scripted([hot] * 20, config=cfg, actuator=act)
    outcomes = [d.outcome for d in ctl.decisions]
    assert outcomes[:3] == ["aborted", "aborted", "applied"]
    assert ctl.decisions[2].detail == ""


def test_unexpected_actuator_error_is_contained():
    """A non-Rebalance exception from the actuator becomes outcome
    'failed' with backoff — the control loop itself never raises."""

    class Exploding(RecordingActuator):
        def split(self, group):
            super().split(group)
            raise RuntimeError("boom")

    hot = [sig(0, docs=500, p95=80.0, reads=50), sig(1, docs=400, reads=40)]
    ctl, _ = run_scripted([hot] * 8, actuator=Exploding(next_gid=2))
    assert ctl.decisions and ctl.decisions[0].outcome == "failed"
    assert "RuntimeError: boom" in ctl.decisions[0].detail


# ------------------------------------------------------------------ #
# the simulated day in the life
# ------------------------------------------------------------------ #
def _run_day(seed, controlled=True, ticks=150):
    clock = SimClock()
    cluster = SimCluster(docs=1200, base_ms=2.0, ms_per_doc=0.05)
    wl = DriftingWorkload(seed=seed, topics=48, reads_per_tick=120,
                          writes_per_tick=8, phase_ticks=50)
    cfg = AutopilotConfig(
        split=HotSplitPolicy(p95_hot_ms=40.0, sustain_ticks=3, min_docs=64,
                             max_groups=8),
        cold=ColdPolicy(demote_after_ticks=15, merge_after_ticks=40,
                        min_groups=2),
        hysteresis=Hysteresis(cooldown_ticks=4, min_dwell_ticks=1,
                              window_ticks=30, max_actions_per_window=6),
        pool=None)
    ctl = Controller(cluster, cluster, config=cfg, clock=clock)
    worst = []
    for _ in range(ticks):
        reads, writes = wl.tick_keys()
        cluster.route(reads)
        cluster.ingest(writes)
        if controlled:
            ctl.tick()
        else:
            cluster.collect()               # same signal drain, no policy
        clock.advance()
        worst.append(max(cluster.base_ms + cluster.ms_per_doc * g.docs
                         for g in cluster.active()))
    return ctl, cluster, worst


def test_sim_day_is_bit_reproducible_per_seed():
    ctl_a, cluster_a, worst_a = _run_day(seed=11)
    ctl_b, cluster_b, worst_b = _run_day(seed=11)
    assert decision_seq(ctl_a) == decision_seq(ctl_b)
    assert cluster_a.actions == cluster_b.actions
    assert worst_a == worst_b
    ctl_c, _, _ = _run_day(seed=12)
    assert decision_seq(ctl_c) != decision_seq(ctl_a)


def test_sim_day_controller_flattens_p95_vs_no_policy_baseline():
    """The headline closed-loop claim, in miniature: under the same
    drifting workload the controlled cluster's worst-group p95 stays
    near its starting value while the uncontrolled one degrades."""
    ctl, cluster, worst_ctl = _run_day(seed=11, controlled=True)
    _, _, worst_base = _run_day(seed=11, controlled=False)
    assert any(d.outcome == "applied" for d in ctl.decisions)
    start = worst_ctl[0]
    assert max(worst_ctl[20:]) <= 1.5 * start
    assert max(worst_base) > max(worst_ctl[20:])


def test_sim_cluster_conserves_docs_across_actions():
    _, cluster, _ = _run_day(seed=11, controlled=True)
    # every ingested doc is owned by exactly one active group
    assert cluster.total_docs() == 1200 + 8 * 150
    for k in [i / 97 for i in range(97)]:
        cluster.owner(k)                    # no key orphaned by split/merge


# ------------------------------------------------------------------ #
# ScatterGather.resize: elastic pool width (PR-4 static sizing fix)
# ------------------------------------------------------------------ #
def test_scatter_resize_completes_inflight_fanout():
    """Resize the pool while a fan-out is blocked mid-flight: the old
    executor's work completes, results stay ordered, and later fan-outs
    use the new width."""
    pool = ScatterGather(workers=2)
    started, release = threading.Event(), threading.Event()

    def thunk(i):
        def run():
            started.set()
            assert release.wait(timeout=30)
            return i
        return run

    out = {}
    t = threading.Thread(
        target=lambda: out.setdefault("r", pool.run([thunk(i)
                                                     for i in range(4)])))
    t.start()
    assert started.wait(timeout=30)
    pool.resize(6)                          # swap width mid-flight
    assert pool.workers == 6
    release.set()
    t.join(timeout=30)
    assert not t.is_alive() and out["r"] == [0, 1, 2, 3]
    assert pool.run([lambda i=i: i * i for i in range(8)]) == \
        [i * i for i in range(8)]
    pool.close()


def test_scatter_resize_validation_and_noops():
    pool = ScatterGather(workers=3)
    with pytest.raises(ValueError):
        pool.resize(0)
    inner = pool._pool
    pool.resize(3)                          # same width: executor untouched
    assert pool._pool is inner
    pool.close()
    pool.resize(8)                          # closed: no-op, stays degraded
    assert pool.workers == 3
    assert pool.run([lambda: 1, lambda: 2]) == [1, 2]


def test_controller_autoscales_pool_to_group_count():
    from repro.dist.autopilot import PoolPolicy

    cfg = tight_config(pool=PoolPolicy(min_workers=2, max_workers=4))
    ticks = [[sig(g, reads=10) for g in range(n)]
             for n in (1, 1, 3, 3, 6, 6)]
    pool = ScatterGather(workers=8)
    clock = SimClock()
    ctl = Controller(ScriptedSignals(ticks), RecordingActuator(),
                     config=cfg, clock=clock, pool=pool)
    widths = []
    for _ in range(len(ticks)):
        ctl.tick()
        widths.append(pool.workers)
        clock.advance()
    assert widths == [2, 2, 3, 3, 4, 4]     # clamped to [min, max]
    pool.close()


# ------------------------------------------------------------------ #
# the real-warren closed loop (live signals + live actuator)
# ------------------------------------------------------------------ #
def test_closed_loop_on_real_warren_split_resync_demote(tmp_path):
    """End to end on a live ShardedWarren: the controller (real
    WarrenSignals + WarrenActuator, fake clock) splits a hot group,
    resurrects a killed replica via anti-entropy, and demotes the
    collection once traffic stops — with served rankings bit-identical
    to a single index after every action."""
    from test_rebalance import QUERIES, _assert_search_parity, _ingest

    from repro.core import DynamicIndex, Warren
    from repro.dist.shard_router import ShardedWarren

    sharded = ShardedWarren(n_shards=2, replicas=2,
                            static_dir=str(tmp_path))
    single = Warren(DynamicIndex())
    _ingest(sharded, range(80))
    _ingest(single, range(80))

    clock = SimClock()
    cfg = AutopilotConfig(
        split=HotSplitPolicy(p95_hot_ms=0.0, sustain_ticks=2, min_docs=1,
                             max_groups=3),
        cold=ColdPolicy(demote_after_ticks=2, merge_after_ticks=10 ** 6,
                        min_groups=1),
        anti_entropy=AntiEntropyPolicy(max_seq_lag=0, sustain_ticks=2),
        hysteresis=Hysteresis(cooldown_ticks=1, min_dwell_ticks=0,
                              window_ticks=50, max_actions_per_window=50),
        pool=None)
    ctl = Controller.for_warren(sharded, config=cfg, clock=clock)

    def serve():
        with sharded:
            for q in QUERIES:
                sharded.search(q, k=10)

    # phase 1 — traffic makes every group "hot" (p95 threshold 0); after
    # sustain_ticks the controller splits the largest group, then
    # max_groups caps further growth
    for _ in range(3):
        serve()
        ctl.tick()
        clock.advance()
    splits = [d for d in ctl.decisions if d.kind == "split"]
    assert [d.outcome for d in splits] == ["applied"]
    assert sharded.n_shards == 3 and sharded.routing.epoch == 1
    with sharded, single:
        _assert_search_parity(sharded, single)

    # phase 2 — kill a replica; anti-entropy re-syncs it (dead streak
    # reaches sustain_ticks) and the pair ends in address lockstep
    sharded.groups[0].mark_failed(1)
    for _ in range(4):
        serve()
        ctl.tick()
        clock.advance()
    resyncs = [d for d in ctl.decisions if d.kind == "resync"]
    assert resyncs and resyncs[0].outcome == "applied"
    assert (resyncs[0].group, resyncs[0].target) == (0, 1)
    grp = sharded.groups[0]
    assert all(grp.alive)
    assert grp.replicas[0]._next_addr == grp.replicas[1]._next_addr
    with sharded, single:
        _assert_search_parity(sharded, single)

    # phase 3 — traffic stops; the idle streak demotes a group to its
    # static run set and reads still serve, bit-identical
    for _ in range(4):
        ctl.tick()
        clock.advance()
    demotes = [d for d in ctl.decisions if d.kind == "demote"]
    assert demotes and demotes[0].outcome == "applied"
    assert any(d is not None for d in sharded.demoted())
    with sharded, single:
        _assert_search_parity(sharded, single)
    assert not any(d.outcome == "failed" for d in ctl.decisions)


def test_warren_signals_are_windowed(tmp_path):
    """WarrenSignals reports per-window deltas: reads/latency observed
    between two collects show up once, then reset."""
    from test_rebalance import QUERIES, _ingest

    from repro.dist.shard_router import ShardedWarren

    sharded = ShardedWarren(n_shards=2, replicas=1)
    _ingest(sharded, range(40))
    src = WarrenSignals(sharded)
    src.collect()                            # baseline snapshot
    with sharded:
        for q in QUERIES:
            sharded.search(q, k=5)
        total = len(sharded.annotations(":"))   # counts as reads too
    sigs = {s.group: s for s in src.collect()}
    assert sum(s.reads for s in sigs.values()) >= len(QUERIES)
    assert all(s.p95_ms == s.p95_ms for s in sigs.values())  # not NaN
    assert sum(s.docs for s in sigs.values()) == total == 40
    quiet = {s.group: s for s in src.collect()}   # nothing in this window
    assert all(s.reads == 0 for s in quiet.values())
    assert all(s.p95_ms != s.p95_ms for s in quiet.values())  # NaN again


# ------------------------------------------------------------------ #
# chaos: replica kills mid-controller-initiated split (stress marker)
# ------------------------------------------------------------------ #
@pytest.mark.stress
def test_chaos_kill_replicas_mid_controller_split_backoff_reconverge():
    """Kill every source replica mid-copy of a CONTROLLER-initiated
    split: the controller observes RebalanceAborted (table untouched),
    backs off, re-syncs the dead replica through anti-entropy once ops
    re-join the intact one, retries the split after the backoff expires,
    and converges — without ever wedging the rebalance lock."""
    from test_rebalance import _assert_search_parity, _ingest

    from repro.core import DynamicIndex, Warren
    from repro.dist.shard_router import ShardedWarren

    sharded = ShardedWarren(n_shards=2, replicas=2)
    single = Warren(DynamicIndex())
    _ingest(sharded, range(60))
    _ingest(single, range(60))
    table_before = sharded.routing.to_record()

    killed = []

    def kill_all(warren, stage, gid):
        if stage == "after_copy" and not killed:
            for r in range(warren.groups[gid].n_replicas):
                warren.groups[gid].mark_failed(r)
            killed.append(gid)

    sharded.hooks["mid_migration"] = kill_all

    clock = SimClock()
    cfg = AutopilotConfig(
        split=HotSplitPolicy(p95_hot_ms=0.0, sustain_ticks=1, min_docs=1,
                             max_groups=4),
        cold=ColdPolicy(demote_after_ticks=10 ** 6,
                        merge_after_ticks=10 ** 6),
        anti_entropy=AntiEntropyPolicy(max_seq_lag=0, sustain_ticks=1),
        hysteresis=Hysteresis(cooldown_ticks=0, min_dwell_ticks=0,
                              window_ticks=50, max_actions_per_window=50),
        retry=RetryPolicy(base_ticks=1, cap_ticks=4),
        pool=None)
    ctl = Controller.for_warren(sharded, config=cfg, clock=clock)

    def serve():
        from test_rebalance import QUERIES
        with sharded:
            for q in QUERIES:
                sharded.search(q, k=10)

    # tick 0: the controller's split hits the kill — aborted, no torn table
    serve()
    ctl.tick()
    clock.advance()
    assert killed, "hook never fired"
    g_src = killed[0]
    d0 = ctl.decisions[0]
    assert (d0.kind, d0.group, d0.outcome) == ("split", g_src, "aborted")
    assert sharded.routing.to_record() == table_before
    sharded.hooks.clear()
    # ops re-join the intact first replica (its index survived the kill);
    # the controller's anti-entropy handles the truly-dead sibling
    sharded.groups[g_src].alive[0] = True

    for _ in range(6):
        serve()
        ctl.tick()
        clock.advance()

    resyncs = [d for d in ctl.decisions
               if d.kind == "resync" and d.group == g_src]
    assert resyncs and resyncs[0].outcome == "applied"
    retried = [d for d in ctl.decisions
               if d.kind == "split" and d.group == g_src
               and d.outcome == "applied"]
    assert retried and retried[0].tick > d0.tick + 1   # after the backoff
    assert all(all(a) for a in sharded.health())
    # the rebalance lock is free — a manual operation acquires it cleanly
    lock = sharded._ctx["rebalance_lock"]
    assert lock.acquire(blocking=False)
    lock.release()
    with sharded, single:
        _assert_search_parity(sharded, single)

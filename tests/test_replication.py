"""Replicated ShardedWarren: equivalence with a single DynamicIndex.

The property test drives identical random interleaved append / annotate /
erase / commit / abort sequences into a ``ShardedWarren(n_shards=3,
replicas=2)`` and a single-index ``Warren`` and requires identical logical
state: for every feature touched, the same annotation multiset (values +
the text each interval annotates — addresses differ by design, stripes vs.
sequential), and the same ``search()`` top-10.  Runs under real hypothesis
when installed, else the seeded ``repro._compat`` sampler.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DynamicIndex, Warren, index_document
from repro.dist.checkpoint import CheckpointManager
from repro.dist.elastic import repartition_replica_groups
from repro.dist.shard_router import (QuorumError, ReplicaFailure,
                                     ShardedWarren, shard_of)

VOCAB = ["school", "education", "student", "government", "law", "state",
         "stock", "money", "business", "vibration", "conductor", "wind"]


def _doc_text(n: int) -> str:
    words = [VOCAB[(n * 7 + i * (1 + n % 5)) % len(VOCAB)]
             for i in range(3 + n % 6)]
    return " ".join(words)


# ------------------------------------------------------------------ #
# the op interpreter: one logical op stream, two warrens
# ------------------------------------------------------------------ #
def _run_ops(warren, ops):
    """Apply the logical op stream; returns (docids committed, tags used).

    Transactions are batched: append/annotate/erase ops stage logical
    intents, "commit"/"abort" replays the staged batch inside one
    start/end bracket and commits or aborts it.  Annotate/erase targets
    are resolved by docid lookup inside the bracket, so both warrens pick
    the same logical documents regardless of address layout.
    """
    committed = []                 # docids alive (committed, not erased)
    staged = []
    tags = set()
    next_doc = [0]

    def flush(commit: bool):
        if not staged:
            return
        batch, staged[:] = list(staged), []
        with warren:
            warren.transaction()
            appended, erased = [], []
            for op in batch:
                if op[0] == "append":
                    n = next_doc[0]
                    next_doc[0] += 1
                    index_document(warren, _doc_text(n), docid=f"d{n}")
                    appended.append(f"d{n}")
                elif op[0] == "annotate":
                    if not committed:
                        continue
                    docid = committed[op[1] % len(committed)]
                    lst = warren.annotations("docid:" + docid)
                    if not len(lst):
                        continue
                    tag = f"tag{op[1] % 4}:"
                    tags.add(tag)
                    warren.annotate(tag, int(lst.starts[0]),
                                    int(lst.ends[0]), float(op[1] % 7))
                else:  # erase
                    live = [d for d in committed if d not in erased]
                    if not live:
                        continue
                    docid = live[op[1] % len(live)]
                    lst = warren.annotations("docid:" + docid)
                    if not len(lst):
                        continue
                    warren.erase(int(lst.starts[0]), int(lst.ends[0]))
                    erased.append(docid)
            if commit:
                warren.commit()
                committed.extend(appended)
                for d in erased:
                    committed.remove(d)
            else:
                warren.abort()
                next_doc[0] -= len(appended)   # replayed identically later

    for op in ops:
        if op[0] == "commit":
            flush(True)
        elif op[0] == "abort":
            flush(False)
        else:
            staged.append(op)
    flush(True)
    return committed, tags


def _annotation_view(warren, feature):
    """Address-free view of a feature's list: sorted (text, value) pairs."""
    lst = warren.annotations(feature)
    out = []
    for i in range(len(lst)):
        out.append((warren.translate(int(lst.starts[i]), int(lst.ends[i])),
                    float(lst.values[i])))
    return sorted(out, key=lambda t: (t[0] or "", t[1]))


def _search_view(warren, query, k=10):
    """(rounded score, text) pairs, ties grouped as frozensets."""
    hits = warren.search(query, k=k) if isinstance(warren, ShardedWarren) \
        else _single_search(warren, query, k)
    docs = warren.annotations(":")
    ends = {int(s): int(e) for s, e in zip(docs.starts, docs.ends)}
    pairs = [(round(s, 9), warren.translate(d, ends[d])) for d, s in hits]
    groups, i = [], 0
    while i < len(pairs):
        j = i
        while j < len(pairs) and pairs[j][0] == pairs[i][0]:
            j += 1
        groups.append((pairs[i][0], frozenset(t for _, t in pairs[i:j])))
        i = j
    return groups


def _single_search(warren, query, k):
    from repro.core import score_bm25
    return score_bm25(warren, query, k=k)


OPS = st.lists(
    st.tuples(st.sampled_from(["append", "append", "annotate", "erase",
                               "commit", "abort"]),
              st.integers(0, 999)),
    min_size=6, max_size=40)


@settings(max_examples=8, deadline=None)
@given(OPS)
def test_replicated_sharded_equals_single_property(ops):
    sharded = ShardedWarren(n_shards=3, replicas=2)
    single = Warren(DynamicIndex())
    docs_s, tags_s = _run_ops(sharded, ops)
    docs_1, tags_1 = _run_ops(single, ops)
    assert docs_s == docs_1 and tags_s == tags_1

    features = [":"] + sorted(tags_s) + [f"docid:{d}" for d in docs_s]
    with sharded, single:
        for f in features:
            assert _annotation_view(sharded, f) == _annotation_view(single, f), f
        for q in ("school education", "money business state", "wind"):
            assert _search_view(sharded, q) == _search_view(single, q), q


# ------------------------------------------------------------------ #
# deterministic acceptance checks
# ------------------------------------------------------------------ #
def _ingest(warren, n_docs, batch=32):
    n = 0
    while n < n_docs:
        with warren:
            warren.transaction()
            for _ in range(min(batch, n_docs - n)):
                index_document(warren, _doc_text(n), docid=f"d{n}")
                n += 1
            warren.commit()


@pytest.fixture(scope="module")
def replicated_pair():
    sharded = ShardedWarren(n_shards=3, replicas=2)
    single = Warren(DynamicIndex())
    _ingest(sharded, 150)
    _ingest(single, 150)
    return sharded, single


QUERIES = ["school education student", "government law state",
           "stock money business", "vibration conductor wind"]


def test_search_parity_with_one_replica_killed_per_group(replicated_pair):
    """ISSUE acceptance: replicas=2, one replica of EVERY group dead →
    ``search`` still returns the exact single-index top-10 scores."""
    sharded, single = replicated_pair
    for g in range(sharded.n_shards):
        sharded.mark_failed(g, g % 2)       # alternate which replica dies
    try:
        assert all(sum(a) == 1 for a in sharded.health())
        with sharded, single:
            for q in QUERIES:
                ref = _search_view(single, q)
                got = _search_view(sharded, q)
                assert got == ref, q
                np.testing.assert_allclose(
                    [s for _, s in sharded.search(q, k=10)],
                    [s for _, s in _single_search(single, q, 10)], rtol=1e-9)
    finally:
        for g in range(sharded.n_shards):
            sharded.resurrect(g, g % 2)


def test_resurrect_restores_lockstep(replicated_pair):
    """A resurrected replica streams segments from its sibling and ends up
    address-identical (same starts/ends for every feature probed)."""
    sharded, single = replicated_pair
    sharded.mark_failed(1, 0)
    _ingest(sharded, 20)                     # writes the dead replica misses
    _ingest(single, 20)                      # keep the reference in sync
    sharded.resurrect(1, 0)
    for grp in sharded.groups:
        a, b = grp.replicas
        assert a._next_addr == b._next_addr
        assert a._next_seq == b._next_seq
        wa, wb = Warren(a), Warren(b)
        with wa, wb:
            for f in (":", "school", "docid:d0"):
                fv = sharded.featurize(f)
                la, lb = wa.annotations(fv), wb.annotations(fv)
                assert np.array_equal(la.starts, lb.starts)
                assert np.array_equal(la.ends, lb.ends)
                assert np.array_equal(la.values, lb.values)


def test_quorum_abort_is_clean(replicated_pair):
    """Killing a replica below quorum aborts the WHOLE cross-shard
    transaction; nothing is published on any group and the warren keeps
    serving."""
    sharded, single = replicated_pair
    with sharded:
        docs = sharded.annotations(":")
        picks = [(int(docs.starts[i]), int(docs.ends[i]))
                 for i in range(0, len(docs), max(len(docs) // 5, 1))]
    assert len({shard_of(p) for p, _ in picks}) > 1   # cross-shard txn
    sharded.mark_failed(0, 0)                         # group 0: 1/2 < quorum
    try:
        with sharded:
            before = len(sharded.annotations("qtag:"))
            sharded.transaction()
            for p, q in picks:
                sharded.annotate("qtag:", p, q, 1.0)
            with pytest.raises(QuorumError):
                sharded.commit()
        with sharded:                                  # fully aborted
            assert len(sharded.annotations("qtag:")) == before == 0
    finally:
        sharded.resurrect(0, 0)
    with sharded:                                      # retry succeeds
        sharded.transaction()
        for p, q in picks:
            sharded.annotate("qtag:", p, q, 1.0)
        sharded.commit()
    with sharded:
        assert len(sharded.annotations("qtag:")) == len(picks)


def test_read_failover_when_all_replicas_of_a_group_die(replicated_pair):
    sharded, _ = replicated_pair
    sharded.mark_failed(2, 0)
    sharded.mark_failed(2, 1)
    try:
        with pytest.raises(ReplicaFailure):
            with sharded:
                pass
    finally:
        # resurrect needs a live sibling: revive in reverse order
        sharded.groups[2].alive[0] = True      # ops override: force re-join
        sharded.resurrect(2, 1)
    with sharded:
        assert len(sharded.annotations(":")) > 0


def test_replicated_checkpoint_restore_fans_out(tmp_path, replicated_pair):
    """One snapshot per group on save; restore fans each group out to R
    independent replicas that all serve and stay in their stripe."""
    sharded, single = replicated_pair
    cm = CheckpointManager(str(tmp_path), async_write=False)
    sharded.checkpoint(cm, 11)
    restored = ShardedWarren.restore(cm, 11, replicas=2)
    assert restored.n_shards == sharded.n_shards
    assert restored.replicas == 2
    for g, grp in enumerate(restored.groups):
        assert len(grp.replicas) == 2
        for idx in grp.replicas:
            assert shard_of(idx._next_addr) == g
        assert grp.replicas[0] is not grp.replicas[1]
    # kill one replica per group: restored warren still answers exactly
    for g in range(restored.n_shards):
        restored.mark_failed(g, 1)
    with restored, single:
        for q in QUERIES:
            assert _search_view(restored, q) == _search_view(single, q)
    # a shared transaction log across restored replicas is refused
    with pytest.raises(ValueError, match="per-replica"):
        cm.restore_index_replicas(11, name="shard00", n=2,
                                  log_path=str(tmp_path / "shared.log"))


def test_repartition_replica_groups_moves_whole_groups():
    groups = [[f"doc{i}" for i in range(20)],
              [f"doc{i}" for i in range(20, 50)]]
    out = repartition_replica_groups(groups, 3, replicas=2)
    assert len(out) == 3
    flat = [x for grp in out for x in grp[0]]
    assert sorted(flat) == sorted(x for g in groups for x in g)
    for grp in out:
        assert len(grp) == 2
        assert grp[0] == grp[1]              # replicas carry identical state
        assert grp[0] is not grp[1]          # ...in independent lists
    with pytest.raises(ValueError):
        repartition_replica_groups(groups, 3, replicas=0)

"""Dynamic index, Warren lifecycle, transactions, ACID, JSON store, ranking."""

import threading

import numpy as np
import pytest

from repro.core import (DynamicIndex, Warren, add_json, annotate_dates,
                        collection_stats, expand_query, index_document,
                        score_blockmax, score_bm25, score_wand, value_of,
                        build_block_impacts, porter_stem)
from repro.core.index import ERASE_FEATURE


def make_warren(log_path=None):
    return Warren(DynamicIndex(log_path=log_path))


def test_append_translate_roundtrip():
    w = make_warren()
    with w:
        w.transaction()
        lo, hi = w.append("To be or not to be, that is the question")
        remap = w.commit()
    lo, hi = remap(lo), remap(hi)
    with w:
        assert w.translate(lo, hi) == "To be or not to be, that is the question"
        assert w.translate(lo, lo + 5) == "To be or not to be"
        assert w.tokens(lo, lo + 1) == ["to", "be"]


def test_word_annotations_automatic():
    w = make_warren()
    with w:
        w.transaction()
        lo, hi = w.append("the cat sat on the mat")
        remap = w.commit()
    lo, hi = remap(lo), remap(hi)
    with w:
        cat = w.annotations("cat")
        assert list(cat) == [(lo + 1, lo + 1, 0.0)]
        the = w.annotations("the")
        assert [t[0] for t in the] == [lo, lo + 4]


def test_snapshot_isolation():
    w = make_warren()
    with w:
        w.transaction()
        w.append("first doc here")
        w.commit()
    reader = w.clone()
    reader.start()
    before = len(reader.annotations("doc"))
    writer = w.clone()
    with writer:
        writer.transaction()
        writer.append("second doc here")
        writer.commit()
    # reader still sees the old snapshot
    assert len(reader.annotations("doc")) == before == 1
    reader.end()
    reader.start()
    assert len(reader.annotations("doc")) == 2
    reader.end()


def test_abort_leaves_gap_and_no_annotations():
    w = make_warren()
    with w:
        w.transaction()
        w.append("visible words")
        w.commit()
    with w:
        w.transaction()
        lo, hi = w.append("phantom words")
        w.ready()
        w.abort()
    with w:
        assert len(w.annotations("phantom")) == 0
    # the aborted interval is a gap: next commit lands after it
    with w:
        w.transaction()
        lo2, _ = w.append("after gap")
        remap = w.commit()
    lo2 = remap(lo2)
    with w:
        assert lo2 > 1  # address space advanced past the gap
        assert w.translate(lo2, lo2) == "after"


def test_late_annotation_of_earlier_content():
    """The defining feature: annotate content appended by a previous txn."""
    w = make_warren()
    with w:
        w.transaction()
        lo, hi = w.append("some earlier content")
        remap = w.commit()
    lo, hi = remap(lo), remap(hi)
    with w:
        w.transaction()
        w.annotate("sentence:", lo, hi, 3.0)
        w.commit()
    with w:
        got = list(w.annotations("sentence:"))
        assert got == [(lo, hi, 3.0)]


def test_erase_hides_content_and_annotations():
    w = make_warren()
    with w:
        w.transaction()
        lo1, hi1 = w.append("doc one alpha")
        w.annotate(":", lo1, hi1)
        lo2, hi2 = w.append("doc two beta")
        w.annotate(":", lo2, hi2)
        remap = w.commit()
    lo1, hi1, lo2, hi2 = remap(lo1), remap(hi1), remap(lo2), remap(hi2)
    with w:
        w.transaction()
        w.erase(lo1, hi1)
        w.commit()
    with w:
        assert w.translate(lo1, hi1) is None
        assert len(w.annotations("alpha")) == 0
        assert len(w.annotations("beta")) == 1
        roots = w.annotations(":")
        assert list(roots) == [(lo2, hi2, 0.0)]


def test_nesting_conflict_keeps_innermost_and_seqnum_tiebreak():
    w = make_warren()
    with w:
        w.transaction()
        lo, hi = w.append("a b c d e f")
        remap = w.commit()
    lo, hi = remap(lo), remap(hi)
    with w:
        w.transaction()
        w.annotate("mark:", lo, hi, 1.0)       # outer
        w.commit()
    with w:
        w.transaction()
        w.annotate("mark:", lo + 1, lo + 2, 2.0)  # inner: wins
        w.annotate("same:", lo, lo + 1, 1.0)
        w.commit()
    with w:
        w.transaction()
        w.annotate("same:", lo, lo + 1, 9.0)   # same interval: larger seq wins
        w.commit()
    with w:
        assert list(w.annotations("mark:")) == [(lo + 1, lo + 2, 2.0)]
        assert list(w.annotations("same:")) == [(lo, lo + 1, 9.0)]


def test_durability_and_recovery(tmp_path):
    path = str(tmp_path / "txn.log")
    w = make_warren(path)
    with w:
        w.transaction()
        lo, hi = w.append("durable little document")
        w.annotate(":", lo, hi)
        remap = w.commit()
    lo, hi = remap(lo), remap(hi)
    with w:
        w.transaction()
        w.append("uncommitted stuff")
        w.ready()
        # crash before commit: simply drop the txn (no commit record)
    w.index._log.close()

    recovered = Warren(DynamicIndex.recover(path))
    with recovered:
        assert recovered.translate(lo, hi) == "durable little document"
        assert len(recovered.annotations("uncommitted")) == 0
        assert len(recovered.annotations("durable")) == 1
    # new writes allocate past the aborted interval
    with recovered:
        recovered.transaction()
        lo2, _ = recovered.append("post recovery")
        remap = recovered.commit()
    assert remap(lo2) >= hi + 1


def test_merge_segments_compacts(tmp_path):
    path = str(tmp_path / "txn.log")
    w = make_warren(path)
    for i in range(8):
        with w:
            w.transaction()
            lo, hi = w.append(f"document number {i} payload")
            w.annotate(":", lo, hi)
            w.commit()
    with w:
        w.transaction()
        docs = w.annotations(":")
        w.erase(int(docs.starts[0]), int(docs.ends[0]))
        w.commit()
    w.index.merge_segments()
    assert len(w.index._segments) == 1
    with w:
        assert len(w.annotations(":")) == 7
        assert len(w.annotations("number")) == 7
    # recovery from the compacted log
    w.index._log.close()
    rec = Warren(DynamicIndex.recover(path))
    with rec:
        assert len(rec.annotations(":")) == 7


def test_concurrent_readers_writers():
    """Many writers + readers; every snapshot internally consistent."""
    w = make_warren()
    stop = threading.Event()
    errors = []

    def writer(tid):
        wc = w.clone()
        for i in range(20):
            with wc:
                wc.transaction()
                index_document(wc, f"thread {tid} doc {i} words shared zebra")
                wc.commit()

    def reader():
        rc = w.clone()
        while not stop.is_set():
            with rc:
                docs = rc.annotations(":")
                dls = rc.annotations("dl:")
                # consistency: every committed doc has its dl: annotation
                if len(docs) != len(dls):
                    errors.append((len(docs), len(dls)))

    writers = [threading.Thread(target=writer, args=(t,)) for t in range(6)]
    readers = [threading.Thread(target=reader) for _ in range(4)]
    for t in readers + writers:
        t.start()
    for t in writers:
        t.join()
    stop.set()
    for t in readers:
        t.join()
    assert not errors, f"inconsistent snapshots: {errors[:5]}"
    with w:
        assert len(w.annotations(":")) == 120


# ------------------------------------------------------------------ #
# JSON store
# ------------------------------------------------------------------ #
SAMPLE = {
    "id": "0001", "type": "donut", "name": "Cake", "ppu": 0.55,
    "batters": {"batter": [{"id": "1001", "type": "Regular"},
                           {"id": "1002", "type": "Chocolate"}]},
    "topping": [{"id": "5001", "type": "None"},
                {"id": "5002", "type": "Glazed"}],
}


def test_json_store_paths_values_and_translate():
    w = make_warren()
    with w:
        w.transaction()
        lo, hi = add_json(w, SAMPLE, collection="Files/sample.json")
        remap = w.commit()
    lo, hi = remap(lo), remap(hi)
    with w:
        # root and collection features
        assert list(w.annotations(":"))[0][:2] == (lo, hi)
        assert list(w.annotations("Files/sample.json"))[0][:2] == (lo, hi)
        # nested path feature
        t = list(w.annotations(":batters:batter:[1]:type:"))
        assert len(t) == 1
        assert value_of(w, int(t[0][0]), int(t[0][1])) == "chocolate"
        # numeric value stored as annotation value
        ppu = list(w.annotations(":ppu:"))
        assert ppu[0][2] == pytest.approx(0.55)
        # array length as value
        arr = list(w.annotations(":batters:batter:"))
        assert arr[0][2] == 2.0
        # structural containment: type value inside element 1 extent
        el = list(w.annotations(":batters:batter:[1]:"))[0]
        assert el[0] <= t[0][0] and t[0][1] <= el[1]


def test_json_heterogeneous_dates():
    w = make_warren()
    objs = [
        {"name": "a", "created": "Feb 20 2015"},
        {"name": "b", "created_at": {"$date": 1180075887000}},  # 2007-05-25
        {"name": "c", "created": "2008-12-01T10:00:00"},
        {"name": "d"},
    ]
    with w:
        w.transaction()
        for o in objs:
            add_json(w, o, collection="Files/mixed.json")
        w.commit()
    with w:
        w.transaction()
        n = annotate_dates(w, [":created:", ":created_at:$date:"])
        w.commit()
    assert n == 3
    with w:
        y2008 = w.hopper("year=2008")
        roots = w.hopper(":")
        from repro.core.gcl import Containing
        got = Containing(roots, y2008).solutions()
        assert len(got) == 1


# ------------------------------------------------------------------ #
# ranking
# ------------------------------------------------------------------ #
DOCS = [
    "the quick brown fox jumps over the lazy dog",
    "a quick brown cat sleeps on the warm mat",
    "foxes and dogs are natural enemies said the fox",
    "the stock market rallied as tech shares jumped",
    "lazy afternoons with a good book and warm tea",
    "the fox hunted the quick rabbit through the brush",
]


def ranked_index():
    w = make_warren()
    with w:
        w.transaction()
        for i, d in enumerate(DOCS):
            index_document(w, d, docid=str(i))
        w.commit()
    return w


def test_bm25_sanity():
    w = ranked_index()
    with w:
        stats = collection_stats(w)
        assert stats.n_docs == len(DOCS)
        top = score_bm25(w, "quick fox", k=3, stats=stats)
        assert top, "no results"
        best = w.translate(top[0][0], int(stats.doc_ends[list(stats.doc_starts).index(top[0][0])]))
        assert "fox" in best


def test_wand_and_blockmax_match_exhaustive():
    w = ranked_index()
    with w:
        stats = collection_stats(w)
        def canon(res):
            return sorted(((d, round(s, 9)) for d, s in res),
                          key=lambda t: (-t[1], t[0]))

        for q in ["quick fox", "lazy dog warm", "stock market", "fox"]:
            exact = score_bm25(w, q, k=4, stats=stats)
            wand = score_wand(w, q, k=4, stats=stats)
            assert canon(wand) == canon(exact)
            bidx = build_block_impacts(w, list(dict.fromkeys(q.split())),
                                       block_size=2, stats=stats)
            bm = score_blockmax(bidx, k=4)
            assert canon(bm) == canon(exact)


def test_prf_expansion_adds_terms():
    w = ranked_index()
    with w:
        weights = expand_query(w, "fox", fb_docs=3, fb_terms=5)
        assert "fox" in weights
        assert len(weights) > 1
        top = score_bm25(w, "", k=3, weights=weights)
        assert top


def test_porter_examples():
    cases = {"caresses": "caress", "ponies": "poni", "relational": "relat",
             "conditional": "condit", "rational": "ration",
             "hopping": "hop", "falling": "fall", "happy": "happi",
             "electricity": "electr", "adjustable": "adjust"}
    for w, s in cases.items():
        assert porter_stem(w) == s, (w, porter_stem(w), s)

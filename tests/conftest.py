import pytest

# Environments without the real hypothesis still run the property tests,
# as seeded random sampling (no shrinking) — see repro/_compat.
from repro._compat import hypothesis_stub

hypothesis_stub.install()


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    # the stress marker is registered once, in pyproject.toml

import os

import pytest

# Environments without the real hypothesis still run the property tests,
# as seeded random sampling (no shrinking) — see repro/_compat.
from repro._compat import hypothesis_stub

hypothesis_stub.install()


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    # the stress marker is registered once, in pyproject.toml


@pytest.fixture(scope="session", autouse=True)
def lock_witness():
    """With REPRO_LOCK_WITNESS=1, every ProfiledLock in the process
    reports to a LockWitness configured from analysis/lock_hierarchy.toml
    for the whole session; any observed acquisition order contradicting
    the declared hierarchy (or completing a cycle) fails the suite at
    teardown.  Off by default: zero setup, one is-None test per lock op."""
    if os.environ.get("REPRO_LOCK_WITNESS") != "1":
        yield None
        return
    from repro import obs

    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    hierarchy = os.path.join(here, "analysis", "lock_hierarchy.toml")
    w = obs.install_witness(obs.LockWitness.from_hierarchy(hierarchy))
    try:
        yield w
        w.check()
    finally:
        obs.uninstall_witness()

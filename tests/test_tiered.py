"""Tiered storage engine: equivalence with a single DynamicIndex, manifest
crash recovery, non-blocking compaction, auto-merge policy, cold-shard
demotion, and merged hot+cold serving.

The property test drives identical random interleaved append / annotate /
erase / commit / abort sequences into a ``TieredWarren`` (with forced
mid-sequence freezes and run compactions) and a plain single-index
``Warren``; because both sides allocate addresses from one sequential hot
index, every feature's annotation list, every ``translate``, and the BM25
top-10 must be *bit-identical*.  Runs under real hypothesis when
installed, else the seeded ``repro._compat`` sampler.
"""

import os
import tempfile
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DynamicIndex, Warren, index_document, score_bm25,
                        write_static)
from repro.tiered import (Compactor, Manifest, ManifestStore, TieredStore,
                          demote_index, resurrect_index)

VOCAB = ["school", "education", "student", "government", "law", "state",
         "stock", "money", "business", "vibration", "conductor", "wind"]


def _doc_text(n: int) -> str:
    words = [VOCAB[(n * 7 + i * (1 + n % 5)) % len(VOCAB)]
             for i in range(3 + n % 6)]
    return " ".join(words)


# ------------------------------------------------------------------ #
# the op interpreter: one logical op stream, either warren
# ------------------------------------------------------------------ #
def _apply_ops(warren, ops, store=None):
    """Apply the op stream; freeze/compact ops act only when ``store`` is
    given (the tiered side) but flush the staged batch on both sides so
    the two op streams stay transaction-aligned.  Returns committed doc
    extents (identical between sides by sequential address allocation)."""
    docs, staged = [], []

    def flush(commit: bool):
        nonlocal staged
        batch, staged = staged, []
        if not batch:
            return
        with warren:
            warren.transaction()
            spans = []
            for op in batch:
                kind, a, b, c = op
                if kind == "append":
                    spans.append(index_document(warren, _doc_text(a),
                                                docid=f"d{a}"))
                elif kind == "annotate" and docs:
                    lo, hi = docs[a % len(docs)]
                    warren.annotate(f"tag{b % 4}:", lo, hi, float(c))
                elif kind == "erase" and docs:
                    lo, hi = docs[a % len(docs)]
                    warren.erase(lo, hi)
            if commit:
                remap = warren.commit()
                docs.extend((remap(lo), remap(hi)) for lo, hi in spans)
            else:
                warren.abort()

    for op in ops:
        kind = op[0]
        if kind == "commit":
            flush(True)
        elif kind == "abort":
            flush(False)
        elif kind == "freeze":
            flush(True)
            if store is not None:
                store.freeze()
        elif kind == "compact":
            flush(True)
            if store is not None:
                store.compact_runs()
        else:
            staged.append(op)
    flush(True)
    return docs


_OPS = st.lists(
    st.tuples(st.sampled_from(["append", "append", "append", "annotate",
                               "erase", "commit", "abort", "freeze",
                               "compact"]),
              st.integers(0, 30), st.integers(0, 10), st.integers(0, 100)),
    min_size=4, max_size=36)


@settings(max_examples=12, deadline=None)
@given(_OPS)
def test_tiered_equivalence_property(ops):
    ref = Warren(DynamicIndex())
    with tempfile.TemporaryDirectory() as td:
        store = TieredStore(td + "/t", auto_merge_threshold=3)
        tw = store.warren()
        docs_t = _apply_ops(tw, ops, store=store)
        docs_r = _apply_ops(ref, ops, store=None)
        assert docs_t == docs_r            # identical address layout

        features = ([":", "dl:"] + [f"tag{i}:" for i in range(4)]
                    + [f"docid:d{i}" for i in range(31)]
                    + VOCAB)
        with tw, ref:
            for f in features:
                assert tw.annotations(f) == ref.annotations(f), f
            for lo, hi in docs_r:
                assert tw.translate(lo, hi) == ref.translate(lo, hi)
                assert tw.tokens(lo, hi) == ref.tokens(lo, hi)
            q = " ".join(VOCAB[:4])
            assert score_bm25(tw, q, k=10) == score_bm25(ref, q, k=10)
        store.close()


# ------------------------------------------------------------------ #
# manifest crash recovery
# ------------------------------------------------------------------ #
def _build(store, n=12, per_txn=4):
    w = store.warren()
    for i in range(0, n, per_txn):
        with w:
            w.transaction()
            for j in range(i, min(i + per_txn, n)):
                index_document(w, _doc_text(j), docid=f"d{j}")
            w.commit()
    return w


def test_crash_between_run_write_and_manifest_swap(tmp_path):
    """The run lands on disk but the manifest swap never happens: recovery
    serves everything from the WAL (latest-good manifest) and GCs the
    orphaned — potentially torn — run directory."""
    d = str(tmp_path / "t")
    store = TieredStore(d)
    _build(store, n=10)
    boom = RuntimeError("simulated crash before manifest publish")

    def crash(_m):
        raise boom
    store.manifests.publish = crash
    with pytest.raises(RuntimeError):
        store.freeze()
    store.close()

    runs_dir = os.path.join(d, "runs")
    assert os.listdir(runs_dir)            # the orphan run is on disk

    store2 = TieredStore(d)
    assert store2.n_runs == 0              # latest-good manifest: no runs
    assert os.listdir(runs_dir) == []      # orphan GC'd, no torn runs live
    w = store2.warren()
    with w:
        assert len(w.annotations(":")) == 10
        assert len(w.annotations("docid:d7")) == 1
    store2.close()


def test_torn_manifest_falls_back_to_latest_good(tmp_path):
    d = str(tmp_path / "t")
    store = TieredStore(d)
    _build(store, n=8)
    store.freeze()
    good_version = store.manifest.version
    store.close()
    # a torn (half-written) higher manifest version from a crash
    with open(os.path.join(d, f"MANIFEST-{good_version + 1:08d}.json"),
              "w") as fh:
        fh.write('{"crc": 1, "manifest": {"version": ')
    store2 = TieredStore(d)
    assert store2.manifest.version == good_version
    w = store2.warren()
    with w:
        assert len(w.annotations(":")) == 8
    store2.close()


def test_crash_after_manifest_before_wal_compaction(tmp_path):
    """Manifest published, hot tier detached, but the WAL still holds the
    frozen segments: reopening must not double-count them."""
    d = str(tmp_path / "t")
    store = TieredStore(d)
    _build(store, n=9)

    orig = store.hot.compact_log

    def crash():
        if store.manifest.frozen_upto >= 0:   # only the post-swap call
            raise RuntimeError("simulated crash before WAL compaction")
        orig()
    store.hot.compact_log = crash
    with pytest.raises(RuntimeError):
        store.freeze()
    assert store.manifest.frozen_upto >= 0
    store.hot._log.close()

    store2 = TieredStore(d)
    assert store2.n_runs == 1
    w = store2.warren()
    with w:
        assert len(w.annotations(":")) == 9          # not 18
        assert len(w.annotations("docid:d3")) == 1
    store2.close()


def test_freeze_never_strands_a_pending_lower_seq_txn(tmp_path):
    """A readied-but-uncommitted transaction sits below later commits in
    seqnum order; a freeze must not advance frozen_upto past it, or its
    eventual commit would be discarded as "already frozen" on reopen."""
    d = str(tmp_path / "t")
    store = TieredStore(d)
    w = _build(store, n=4)
    pending = store.hot.transaction()
    pending.append("pendingalpha limbo tokens")
    pending.ready()                          # durable phase 1, no commit
    with w:
        w.transaction()
        index_document(w, _doc_text(99), docid="d99")   # higher seqnum
        w.commit()
    store.freeze()
    assert store.manifest.frozen_upto < pending._segment.seqnum
    pending.commit()                         # acknowledged-committed
    store.close()

    store2 = TieredStore(d)
    w2 = store2.warren()
    with w2:
        assert len(w2.annotations("pendingalpha")) == 1
        assert len(w2.annotations("docid:d99")) == 1
        assert len(w2.annotations(":")) == 5
    store2.close()


def test_commit_racing_a_group_demotion_is_not_lost(tmp_path):
    """A transaction staged before its group is demoted must survive: the
    quorum commit promotes the group back instead of publishing onto the
    wiped replicas of a cold group."""
    from repro.dist.shard_router import ShardedWarren

    w = ShardedWarren(n_shards=1, replicas=2, static_dir=str(tmp_path))
    with w:
        w.transaction()
        for i in range(4):
            index_document(w, _doc_text(i), docid=f"d{i}")
        w.commit()

    writer = w.clone()
    writer.start()
    writer.transaction()
    index_document(writer, "late racing document", docid="dlate")
    w.demote_group(0)                        # demotion wins the race
    assert w.demoted()[0] is not None
    writer.commit()                          # must promote, then publish
    writer.end()

    assert w.demoted()[0] is None
    with w:
        assert len(w.annotations("docid:dlate")) == 1
        assert len(w.annotations(":")) == 5
        lst = w.annotations("docid:dlate")
        assert w.translate(int(lst.starts[0]),
                           int(lst.ends[0])) == "late racing document"


# ------------------------------------------------------------------ #
# compaction runs concurrently with readers, never blocking a pinned
# snapshot
# ------------------------------------------------------------------ #
def test_pinned_reader_during_concurrent_compaction(tmp_path):
    store = TieredStore(str(tmp_path / "t"))
    w = _build(store, n=24, per_txn=4)
    with w:
        expect_docs = w.annotations(":")
        lo, hi = int(expect_docs.starts[0]), int(expect_docs.ends[0])
        expect_text = w.translate(lo, hi)

    # slow the maintenance path down so reads demonstrably overlap it
    orig_publish = store.manifests.publish

    def slow_publish(m):
        time.sleep(0.15)
        orig_publish(m)
    store.manifests.publish = slow_publish

    w.start()                                # pin a pre-compaction view
    done = threading.Event()
    errors = []

    def maintain():
        try:
            store.freeze()
            store.freeze()                   # no-op: nothing new committed
            store.compact_runs(min_runs=1)
        except Exception as e:               # pragma: no cover
            errors.append(e)
        finally:
            done.set()

    t = threading.Thread(target=maintain)
    t.start()
    reads = 0
    while not done.is_set():
        assert w.annotations(":") == expect_docs
        assert w.translate(lo, hi) == expect_text
        reads += 1
    t.join()
    w.end()
    assert not errors
    assert reads > 3                         # reader made progress throughout
    assert store.metrics.n_freezes == 1
    with w:                                  # post-compaction view agrees
        assert w.annotations(":") == expect_docs
        assert w.translate(lo, hi) == expect_text
    store.close()


# ------------------------------------------------------------------ #
# hot-tier size-tiered auto-merge policy
# ------------------------------------------------------------------ #
def test_auto_merge_policy_bounds_segment_count():
    idx = DynamicIndex(auto_merge_threshold=4)
    w = Warren(idx)
    for i in range(14):
        with w:
            w.transaction()
            index_document(w, _doc_text(i), docid=f"d{i}")
            w.commit()
    assert len(idx._segments) <= 5           # merged back under the cap
    with w:
        assert len(w.annotations(":")) == 14
        d = w.annotations("docid:d11")
        assert w.translate(int(d.starts[0]), int(d.ends[0])) == _doc_text(11)


def test_default_behavior_never_auto_merges():
    idx = DynamicIndex()
    w = Warren(idx)
    for i in range(8):
        with w:
            w.transaction()
            index_document(w, _doc_text(i))
            w.commit()
    assert len(idx._segments) == 8


# ------------------------------------------------------------------ #
# cold-shard demotion on the ShardedWarren
# ------------------------------------------------------------------ #
def test_sharded_demote_query_parity_and_write_promotion(tmp_path):
    from repro.dist.shard_router import ShardedWarren

    w = ShardedWarren(n_shards=3, replicas=2, static_dir=str(tmp_path))
    for i in range(0, 36, 6):
        with w:
            w.transaction()
            for j in range(i, i + 6):
                index_document(w, _doc_text(j), docid=f"d{j}")
            w.commit()
    with w:
        before = w.search("school education student", k=10)
        d5 = w.annotations("docid:d5")
        span5 = (int(d5.starts[0]), int(d5.ends[0]))
        text5 = w.translate(*span5)

    for g in range(3):
        w.demote_group(g)
    assert all(d is not None for d in w.demoted())

    with w:                                  # all-cold reads: exact parity
        assert w.search("school education student", k=10) == before
        assert w.translate(*span5) == text5
        assert len(w.annotations(":")) == 36
        assert w.search_gcl("[docid:d5]")

    with w:                                  # a write wakes its group only
        w.transaction()
        index_document(w, "fresh hot wind conductor doc", docid="dnew")
        w.commit()
    cold = [d is not None for d in w.demoted()]
    assert cold.count(False) == 1 and cold.count(True) == 2
    with w:                                  # mixed hot+cold scatter-gather
        assert len(w.annotations(":")) == 37
        assert w.translate(*span5) == text5
        assert w.search("wind conductor", k=5)

    for g in range(3):
        w.promote_group(g)
    assert all(d is None for d in w.demoted())
    assert all(all(row) for row in w.health())
    with w:
        assert len(w.annotations(":")) == 37
        assert w.translate(*span5) == text5


def test_demote_resurrect_index_roundtrip(tmp_path):
    idx = DynamicIndex()
    w = Warren(idx)
    for i in range(6):
        with w:
            w.transaction()
            index_document(w, _doc_text(i), docid=f"d{i}")
            w.commit()
    with w:
        lst = w.annotations("docid:d2")
        victim = (int(lst.starts[0]), int(lst.ends[0]))
    with w:
        w.transaction()
        w.erase(*victim)
        w.commit()

    d = str(tmp_path / "cold")
    m = demote_index(idx, d)
    assert m.next_addr == idx._next_addr and m.next_seq == idx._next_seq

    for replica in resurrect_index(d, n=2):
        w2 = Warren(replica)
        with w, w2:
            for f in (":", "docid:d0", "docid:d2", "dl:"):
                assert w2.annotations(f) == w.annotations(f)
            assert w2.translate(*victim) is None
        assert replica._next_addr == idx._next_addr
        assert replica._next_seq == idx._next_seq


# ------------------------------------------------------------------ #
# serving: RetrievalServer scores merged hot+cold lists
# ------------------------------------------------------------------ #
def test_retrieval_server_over_tiered_warren(tmp_path):
    from repro.train.serve import RetrievalServer

    store = TieredStore(str(tmp_path / "t"))
    w = _build(store, n=20, per_txn=5)
    store.freeze()                           # cold runs...
    with w:
        w.transaction()
        index_document(w, _doc_text(3) + " school education", docid="dhot")
        w.commit()                           # ...plus a hot segment on top
    with w:
        host = score_bm25(w, "school education student", k=10)
        full = dict(score_bm25(w, "school education student", k=21))
    server = RetrievalServer(w, k=10)
    server.refresh_stats()
    got = server.query("school education student", timeout=30)
    server.close()
    # same score profile; doc order may differ only within exact ties
    np.testing.assert_allclose([s for _, s in got],
                               [s for _, s in host], rtol=1e-5)
    for d, s in got:                         # each served doc scored as host
        np.testing.assert_allclose(s, full[d], rtol=1e-5)
    store.close()


# ------------------------------------------------------------------ #
# background compactor end-to-end
# ------------------------------------------------------------------ #
def test_background_compactor_converges(tmp_path):
    store = TieredStore(str(tmp_path / "t"), auto_merge_threshold=4)
    compactor = Compactor(store, freeze_segments=2, max_runs=2,
                          interval_s=0.01).start()
    w = store.warren()
    for i in range(0, 30, 3):
        with w:
            w.transaction()
            for j in range(i, i + 3):
                index_document(w, _doc_text(j), docid=f"d{j}")
            w.commit()
    compactor.stop(drain=True)
    assert store.metrics.n_freezes >= 1
    assert store.n_runs <= 2 + 1
    with w:
        assert len(w.annotations(":")) == 30
        assert score_bm25(w, "school education", k=10)
    store.close()

"""Live shard rebalancing: split/merge replica groups under load.

Tier-1 here covers the acceptance criteria of the rebalancing issue: a
live split (and merge) is bit-identical to a single-index oracle —
including random op interleavings and ops issued *during* a split with
concurrent writers — readers are never aborted, tombstones survive the
partition, transactions staged across a swap are re-staged transparently,
demoted groups merge by shipping run manifests (no promotion), and the
routing table round-trips through checkpoints.  The chaos variants
(replica kills mid-migration) live behind the ``stress`` marker.
"""

import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DynamicIndex, Warren, index_document, score_bm25
from repro.dist.checkpoint import CheckpointManager
from repro.dist.elastic import (merge_shard_groups, repartition_replica_groups,
                                repartition_shards, split_shard_group)
from repro.dist.rebalance import (RebalanceAborted, RebalanceError,
                                  Rebalancer)
from repro.dist.shard_router import ShardedWarren

VOCAB = ["school", "education", "student", "government", "law", "state",
         "stock", "money", "business", "vibration", "conductor", "wind"]

QUERIES = ["school education student", "government law state",
           "stock money business", "vibration conductor wind"]


def _text(n: int) -> str:
    return " ".join(VOCAB[(n * 7 + i * (1 + n % 5)) % len(VOCAB)]
                    for i in range(3 + n % 6))


def _ingest(warren, ids, batch=16):
    ids = list(ids)
    while ids:
        chunk, ids = ids[:batch], ids[batch:]
        with warren:
            warren.transaction()
            for n in chunk:
                index_document(warren, _text(n), docid=f"d{n}")
            warren.commit()


def _erase_doc(warren, docid):
    with warren:
        lst = warren.annotations("docid:" + docid)
        assert len(lst) == 1
        warren.transaction()
        warren.erase(int(lst.starts[0]), int(lst.ends[0]))
        warren.commit()


def _annotation_view(warren, feature):
    """Address-free view of a feature's list: sorted (text, value) pairs."""
    lst = warren.annotations(feature)
    out = []
    for i in range(len(lst)):
        out.append((warren.translate(int(lst.starts[i]), int(lst.ends[i])),
                    float(lst.values[i])))
    return sorted(out, key=lambda t: (t[0] or "", t[1]))


def _assert_search_parity(sharded, single, queries=QUERIES, k=10):
    for q in queries:
        got = sharded.search(q, k=k)
        ref = score_bm25(single, q, k=k)
        np.testing.assert_allclose([s for _, s in got],
                                   [s for _, s in ref], rtol=1e-9)


def _pair(n_docs=120, n_shards=2, replicas=2):
    sharded = ShardedWarren(n_shards=n_shards, replicas=replicas)
    single = Warren(DynamicIndex())
    _ingest(sharded, range(n_docs))
    _ingest(single, range(n_docs))
    return sharded, single


# ------------------------------------------------------------------ #
# deterministic acceptance checks
# ------------------------------------------------------------------ #
def test_live_split_is_bit_identical_to_single_index():
    sharded, single = _pair()
    for d in ("d3", "d40"):                       # tombstones BEFORE the split
        _erase_doc(sharded, d)
        _erase_doc(single, d)
    rb = Rebalancer(sharded)
    new_gid = rb.split_group(0)
    assert new_gid == 2 and sharded.n_shards == 3
    assert sharded.routing.epoch == 1
    stats = rb.last_stats
    assert stats.kind == "split" and stats.swap_s >= 0.0
    for d in ("d7", "d50"):                       # tombstones AFTER the split
        _erase_doc(sharded, d)
        _erase_doc(single, d)
    _ingest(sharded, range(500, 540))             # appends after the split
    _ingest(single, range(500, 540))
    with sharded, single:
        assert len(sharded.annotations(":")) == len(single.annotations(":"))
        for d in ("d3", "d40", "d7", "d50"):
            assert len(sharded.annotations("docid:" + d)) == 0
        feats = [":", "docid:d10", "docid:d80", "docid:d510"]
        for f in feats:
            assert _annotation_view(sharded, f) == _annotation_view(single, f)
        _assert_search_parity(sharded, single)


def test_split_then_merge_roundtrip_and_retired_group_addressable():
    sharded, single = _pair(n_docs=100)
    rb = Rebalancer(sharded)
    new_gid = rb.split_group(0)
    _ingest(sharded, range(700, 720))
    _ingest(single, range(700, 720))
    rb.merge_groups(0, new_gid)
    assert rb.last_stats.kind == "merge"
    grp = sharded.groups[new_gid]
    assert grp.retired
    # retired groups stay addressable: health, demote refusal, empty reads
    assert len(sharded.health()) == sharded.n_shards == 3
    with pytest.raises(ValueError, match="retired"):
        sharded.demote_group(new_gid, "/tmp/never-used")
    with pytest.raises(RebalanceError, match="retired"):
        rb.split_group(new_gid)
    _ingest(sharded, range(800, 830))             # writes after the merge
    _ingest(single, range(800, 830))
    with sharded, single:
        assert len(sharded.annotations(":")) == len(single.annotations(":"))
        for f in (":", "docid:d0", "docid:d705", "docid:d820"):
            assert _annotation_view(sharded, f) == _annotation_view(single, f)
        _assert_search_parity(sharded, single)


def test_native_retrieval_server_is_exact_after_rebalance():
    """The sharded-native serving pipeline (global stats, posting cap,
    device top-k, address-keyed merge) stays bit-identical to ``search``
    after a split has broken the group-order == address-order assumption."""
    from repro.train.serve import RetrievalServer

    sharded, _ = _pair(n_docs=90)
    Rebalancer(sharded).split_group(0)
    # legacy mode scores the warren as ONE merged surface (the single-index
    # device path); native mode runs the per-group pipeline — after a
    # split they must still agree to the last bit, including tie order
    srv_native = RetrievalServer(sharded, k=10, sharded_native=True)
    srv_legacy = RetrievalServer(sharded, k=10, sharded_native=False)
    try:
        got = srv_native._handle(QUERIES)
        ref = srv_legacy._handle(QUERIES)
        for q, g_hits, r_hits in zip(QUERIES, got, ref):
            assert [(d, round(s, 9)) for d, s in g_hits] == \
                [(d, round(s, 9)) for d, s in r_hits], q
    finally:
        srv_native.close()
        srv_legacy.close()


def test_transaction_staged_across_split_is_restaged():
    """A transaction staged against the pre-split topology commits cleanly
    after the swap: the warren re-stages the logical ops against the new
    routing table instead of surfacing RouteEpochError."""
    sharded, single = _pair(n_docs=60, n_shards=2, replicas=1)
    with sharded:
        docs = sharded.annotations(":")
        picks = [(int(docs.starts[i]), int(docs.ends[i]))
                 for i in range(0, len(docs), max(len(docs) // 5, 1))]
    writer = sharded.clone()
    writer.start()
    writer.transaction()
    for p, q in picks:
        writer.annotate("xtag:", p, q, 1.0)
    index_document(writer, _text(999), docid="d999")
    # the swap lands between staging and commit
    Rebalancer(sharded).split_group(0)
    writer.commit()
    writer.end()
    with sharded:
        assert len(sharded.annotations("xtag:")) == len(picks)
        assert len(sharded.annotations("docid:d999")) == 1


def test_split_with_concurrent_writers_and_readers():
    """ISSUE acceptance: live split completes with concurrent writers and
    zero aborted reader transactions; the result matches a single index
    holding exactly the committed documents; the only writer stall is the
    swap (measured)."""
    sharded = ShardedWarren(n_shards=2, replicas=2)
    _ingest(sharded, range(80))
    errors, committed = [], []
    stop = threading.Event()

    def writer(wid):
        wc = sharded.clone()
        for i in range(30):
            n = 1000 + wid * 100 + i
            try:
                with wc:
                    wc.transaction()
                    index_document(wc, _text(n), docid=f"d{n}")
                    wc.commit()
                committed.append(n)
            except Exception as e:            # noqa: BLE001 — test invariant
                errors.append(f"writer d{n}: {type(e).__name__}: {e}")
                return

    def reader():
        wc = sharded.clone()
        seen = 0
        while not stop.is_set():
            try:
                with wc:
                    n = len(wc.annotations(":"))
                    wc.search("school education", k=5)
                if n < seen:
                    errors.append(f"reader went backwards: {n} < {seen}")
                    return
                seen = n
            except Exception as e:            # noqa: BLE001 — zero aborts
                errors.append(f"reader: {type(e).__name__}: {e}")
                return

    writers = [threading.Thread(target=writer, args=(w,)) for w in range(3)]
    readers = [threading.Thread(target=reader) for _ in range(2)]
    for t in writers + readers:
        t.start()
    rb = Rebalancer(sharded)
    new_gid = rb.split_group(0)
    for t in writers:
        t.join(timeout=120)
    stop.set()
    for t in readers:
        t.join(timeout=30)
    assert errors == [], errors
    assert len(committed) == 90
    stats = rb.last_stats
    assert stats.swap_s > 0.0 and stats.segments_streamed > 0

    single = Warren(DynamicIndex())
    _ingest(single, range(80))
    _ingest(single, sorted(committed), batch=1)
    with sharded, single:
        assert len(sharded.annotations(":")) == 80 + len(committed)
        for q in QUERIES:
            got = sorted(s for _, s in sharded.search(q, k=10))
            ref = sorted(s for _, s in score_bm25(single, q, k=10))
            np.testing.assert_allclose(got, ref, rtol=1e-9)
    assert new_gid == 2


def test_merge_demoted_groups_ships_manifests_not_records(tmp_path):
    sharded = ShardedWarren(n_shards=3, replicas=2,
                            static_dir=str(tmp_path))
    single = Warren(DynamicIndex())
    _ingest(sharded, range(90))
    _ingest(single, range(90))
    sharded.demote_group(0)
    sharded.demote_group(1)
    rb = Rebalancer(sharded)
    rb.merge_groups(0, 1)
    assert rb.last_stats.kind == "merge-demoted"
    # no promotion happened: the surviving group is still cold, replicas
    # still hold zero in-memory segments, the run count is the sum
    grp = sharded.groups[0]
    assert grp.demoted is not None
    assert all(len(r._segments) == 0 for r in grp.replicas)
    assert sharded.groups[1].retired and sharded.groups[1].demoted is None
    with sharded, single:
        assert len(sharded.annotations(":")) == 90
        for f in (":", "docid:d5", "docid:d42"):
            assert _annotation_view(sharded, f) == _annotation_view(single, f)
        _assert_search_parity(sharded, single)
    # the first write still promotes the (merged) cold group
    _ingest(sharded, [600])
    _ingest(single, [600])
    with sharded, single:
        assert len(sharded.annotations(":")) == 91
        _assert_search_parity(sharded, single)


def test_split_demoted_ships_sliced_runs_no_promotion(tmp_path):
    """A demoted split ships sliced run sets: neither side is promoted,
    both sides stay cold with zero in-memory segments, tombstones recorded
    before demotion hide content on whichever side they landed, and the
    family is bit-identical to the single-index oracle."""
    sharded = ShardedWarren(n_shards=2, replicas=2,
                            static_dir=str(tmp_path))
    single = Warren(DynamicIndex())
    _ingest(sharded, range(100))
    _ingest(single, range(100))
    for d in ("d3", "d40"):
        _erase_doc(sharded, d)
        _erase_doc(single, d)
    sharded.demote_group(0)
    rb = Rebalancer(sharded)
    new_gid = rb.split_group(0)
    assert rb.last_stats.kind == "split-demoted"
    assert rb.last_stats.segments_streamed >= 1
    src, dst = sharded.groups[0], sharded.groups[new_gid]
    assert src.demoted is not None and dst.demoted is not None
    for grp in (src, dst):
        assert all(len(r._segments) == 0 for r in grp.replicas)
    with sharded, single:
        assert len(sharded.annotations(":")) == 98
        for f in (":", "docid:d5", "docid:d42", "docid:d3"):
            assert _annotation_view(sharded, f) == _annotation_view(single, f)
        _assert_search_parity(sharded, single)
    # tombstones recorded after the split land on the owning side only
    for d in ("d7", "d50"):
        _erase_doc(sharded, d)
        _erase_doc(single, d)
    # both sides keep serving and keep allocating without collisions
    _ingest(sharded, range(500, 540))
    _ingest(single, range(500, 540))
    with sharded, single:
        assert len(sharded.annotations(":")) == 136
        for f in (":", "docid:d520", "docid:d7"):
            assert _annotation_view(sharded, f) == _annotation_view(single, f)
        _assert_search_parity(sharded, single)


def test_routing_table_survives_checkpoint_restore(tmp_path):
    sharded, single = _pair(n_docs=80)
    rb = Rebalancer(sharded)
    new_gid = rb.split_group(0)
    rb.merge_groups(1, new_gid)       # leave a retired group in the family
    _ingest(sharded, range(300, 330))
    _ingest(single, range(300, 330))
    cm = CheckpointManager(str(tmp_path), async_write=False)
    sharded.checkpoint(cm, 13)
    restored = ShardedWarren.restore(cm, 13, replicas=2)
    assert restored.n_shards == sharded.n_shards
    assert restored.routing.to_record() == sharded.routing.to_record()
    assert restored.groups[new_gid].retired
    with restored, single:
        assert len(restored.annotations(":")) == len(single.annotations(":"))
        for f in (":", "docid:d0", "docid:d310"):
            assert _annotation_view(restored, f) == _annotation_view(single, f)
        _assert_search_parity(restored, single)
    # the restored family keeps allocating without address collisions
    _ingest(restored, range(400, 420))
    _ingest(single, range(400, 420))
    with restored, single:
        assert len(restored.annotations(":")) == len(single.annotations(":"))
        _assert_search_parity(restored, single)

    # losing or tearing the routing record of a REBALANCED checkpoint must
    # fail loudly, never silently fall back to striped routing
    import os

    from repro.dist.checkpoint import CheckpointCorrupt
    routing_file = tmp_path / "routing_00000013.routing.json"
    good = routing_file.read_text()
    routing_file.write_text(good.replace('"crc": ', '"crc": 1'))
    with pytest.raises(CheckpointCorrupt, match="routing"):
        ShardedWarren.restore(cm, 13, replicas=2)
    os.unlink(routing_file)
    with pytest.raises(CheckpointCorrupt, match="routing"):
        ShardedWarren.restore(cm, 13, replicas=2)


def test_split_preserves_wal_durability(tmp_path):
    """Regression: a log-backed family must keep EVERY document durable
    across a split — the destination group gets its own per-replica logs
    and the moved half must be recoverable from them after the source
    compacts its logs down to the kept half."""
    from repro.core.index import DynamicIndex as DI

    sharded = ShardedWarren(n_shards=2, replicas=2, log_dir=str(tmp_path))
    _ingest(sharded, range(60))
    new_gid = Rebalancer(sharded).split_group(0)
    _ingest(sharded, range(200, 220))          # post-split commits log too
    with sharded:
        expect = len(sharded.annotations(":"))
    recovered = 0
    for g in range(sharded.n_shards):
        path = tmp_path / f"shard{g:02d}r0.log"
        assert path.exists(), f"group {g} lost its durable log"
        idx = DI.recover(str(path))
        w = Warren(idx)
        with w:
            lst = w.annotations(sharded.featurize(":"))
            recovered += len(lst)
    assert recovered == expect == 80           # nothing lost, nothing doubled
    assert new_gid == 2


def test_repartition_keeps_empty_groups_addressable():
    """Regression: ``k_new > k_old`` leaving shards unpopulated must yield
    exactly k_new groups — empty ones included and replica-fanned — and
    routing must be deterministic across repeated calls."""
    groups = [["only-doc-a", "only-doc-b", "only-doc-c"]]
    out = repartition_replica_groups(groups, 6, replicas=2)
    assert len(out) == 6                           # nothing dropped
    empties = [g for g in out if g[0] == []]
    assert empties, "expected at least one unpopulated group"
    for grp in out:
        assert len(grp) == 2                       # replicas fan out too
        assert grp[0] == grp[1] and grp[0] is not grp[1]
    assert out == repartition_replica_groups(groups, 6, replicas=2)
    flat = [x for grp in out for x in grp[0]]
    assert sorted(flat) == sorted(groups[0])
    with pytest.raises(ValueError):
        repartition_shards(groups, 0)
    with pytest.raises(ValueError, match="returned"):
        repartition_shards(groups, 2, route=lambda item, k: k + 7)


def test_elastic_live_wrappers():
    sharded, single = _pair(n_docs=60, replicas=1)
    new_gid = split_shard_group(sharded, 0)
    merge_shard_groups(sharded, 0, new_gid)
    with sharded, single:
        assert len(sharded.annotations(":")) == 60
        _assert_search_parity(sharded, single)


def test_split_refuses_bad_inputs():
    sharded = ShardedWarren(n_shards=2)
    rb = Rebalancer(sharded)
    with pytest.raises(RebalanceError, match="nothing to split"):
        rb.split_group(0)                       # empty group
    with pytest.raises(RebalanceError, match="no shard group"):
        rb.split_group(7)
    _ingest(sharded, range(20))
    with pytest.raises(RebalanceError, match="not inside"):
        rb.split_group(0, pivot=-5)
    with pytest.raises(RebalanceError):
        rb.merge_groups(1, 1)


# ------------------------------------------------------------------ #
# the property test: random interleavings around a split (+ merge)
# ------------------------------------------------------------------ #
def _run_ops(warren, ops, state):
    """Apply logical ops; targets resolve by docid so both warrens pick the
    same logical documents regardless of address layout."""
    committed, next_doc = state
    for kind, arg in ops:
        if kind == "append":
            n = next_doc[0]
            next_doc[0] += 1
            with warren:
                warren.transaction()
                index_document(warren, _text(n), docid=f"d{n}")
                warren.commit()
            committed.append(f"d{n}")
        elif kind == "annotate":
            if not committed:
                continue
            docid = committed[arg % len(committed)]
            with warren:
                lst = warren.annotations("docid:" + docid)
                if not len(lst):
                    continue
                warren.transaction()
                warren.annotate(f"tag{arg % 4}:", int(lst.starts[0]),
                                int(lst.ends[0]), float(arg % 7))
                warren.commit()
        else:  # erase
            if not committed:
                continue
            docid = committed[arg % len(committed)]
            with warren:
                lst = warren.annotations("docid:" + docid)
                if not len(lst):
                    continue
                warren.transaction()
                warren.erase(int(lst.starts[0]), int(lst.ends[0]))
                warren.commit()
            committed.remove(docid)


OPS = st.lists(
    st.tuples(st.sampled_from(["append", "append", "append", "annotate",
                               "erase"]),
              st.integers(0, 999)),
    min_size=8, max_size=24)


@settings(max_examples=6, deadline=None)
@given(OPS, OPS, st.booleans())
def test_random_ops_around_split_match_single_index(before, after, also_merge):
    sharded = ShardedWarren(n_shards=2, replicas=2)
    single = Warren(DynamicIndex())
    state_s = ([], [0])
    state_1 = ([], [0])
    _ingest(sharded, range(30))          # enough mass to make splits legal
    _ingest(single, range(30))
    state_s[0].extend(f"d{n}" for n in range(30))
    state_1[0].extend(f"d{n}" for n in range(30))
    state_s[1][0] = state_1[1][0] = 30
    _run_ops(sharded, before, state_s)
    _run_ops(single, before, state_1)
    rb = Rebalancer(sharded)
    try:
        new_gid = rb.split_group(0)
    except RebalanceError:
        return     # the op stream erased group 0 down to < 2 documents
    _run_ops(sharded, after, state_s)
    _run_ops(single, after, state_1)
    if also_merge:
        rb.merge_groups(1, new_gid)
    assert state_s[0] == state_1[0]
    features = [":"] + [f"tag{i}:" for i in range(4)] + \
        [f"docid:{d}" for d in state_s[0][:8]]
    with sharded, single:
        for f in features:
            assert _annotation_view(sharded, f) == \
                _annotation_view(single, f), f
        for q in ("school education", "money business state", "wind"):
            got = sharded.search(q, k=10)
            ref = score_bm25(single, q, k=10)
            np.testing.assert_allclose([s for _, s in got],
                                       [s for _, s in ref], rtol=1e-9)


# ------------------------------------------------------------------ #
# chaos: replica kills mid-migration (stress marker, own CI job)
# ------------------------------------------------------------------ #
@pytest.mark.stress
def test_chaos_losing_every_replica_mid_migration_aborts_cleanly():
    """Kill ALL source replicas mid-migration: the swap must abort with no
    torn routing table, and a retry after resurrection must succeed."""
    sharded = ShardedWarren(n_shards=2, replicas=2)
    _ingest(sharded, range(60))
    table_before = sharded.routing.to_record()

    def kill_all(warren, stage, gid):
        if stage == "after_copy":
            for r in range(warren.groups[gid].n_replicas):
                warren.groups[gid].mark_failed(r)

    sharded.hooks["mid_migration"] = kill_all
    rb = Rebalancer(sharded)
    with pytest.raises(RebalanceAborted):
        rb.split_group(0)
    sharded.hooks.clear()
    # no torn state: table unchanged, no half-registered group
    assert sharded.routing.to_record() == table_before
    assert sharded.n_shards == 2
    assert rb.history == []
    # repair (ops override re-joins the intact first replica) and retry
    sharded.groups[0].alive[0] = True
    sharded.resurrect(0, 1)
    new_gid = rb.split_group(0)
    assert new_gid == 2
    single = Warren(DynamicIndex())
    _ingest(single, range(60))
    with sharded, single:
        assert len(sharded.annotations(":")) == 60
        _assert_search_parity(sharded, single)


@pytest.mark.stress
def test_chaos_single_replica_kill_mid_migration_split_survives():
    """Kill one source replica mid-migration while writers run: the split
    streams from a survivor, writers keep committing (R=3 keeps quorum at
    2 with one replica down), and the killed replica resurrects into the
    post-split group in lockstep."""
    sharded = ShardedWarren(n_shards=2, replicas=3)
    _ingest(sharded, range(60))
    killed = []

    def kill_one(warren, stage, gid):
        if stage == "after_copy" and not killed:
            warren.groups[gid].mark_failed(1)
            killed.append((gid, 1))

    sharded.hooks["mid_migration"] = kill_one
    errors, committed = [], []

    def writer(wid):
        wc = sharded.clone()
        for i in range(25):
            n = 2000 + wid * 100 + i
            try:
                with wc:
                    wc.transaction()
                    index_document(wc, _text(n), docid=f"d{n}")
                    wc.commit()
                committed.append(n)
            except Exception as e:            # noqa: BLE001
                errors.append(f"writer d{n}: {type(e).__name__}: {e}")
                return

    writers = [threading.Thread(target=writer, args=(w,)) for w in range(2)]
    for t in writers:
        t.start()
    rb = Rebalancer(sharded)
    new_gid = rb.split_group(0)
    for t in writers:
        t.join(timeout=120)
    sharded.hooks.clear()
    assert errors == [], errors
    assert killed == [(0, 1)]
    sharded.resurrect(0, 1)
    grp = sharded.groups[0]
    a, b, c = grp.replicas
    assert a._next_addr == b._next_addr == c._next_addr
    assert a._next_seq == b._next_seq == c._next_seq
    single = Warren(DynamicIndex())
    _ingest(single, range(60))
    _ingest(single, sorted(committed), batch=1)
    with sharded, single:
        assert len(sharded.annotations(":")) == 60 + len(committed)
        for q in QUERIES:
            np.testing.assert_allclose(
                sorted(s for _, s in sharded.search(q, k=10)),
                sorted(s for _, s in score_bm25(single, q, k=10)), rtol=1e-9)
    assert new_gid == 2

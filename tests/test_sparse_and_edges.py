"""Learned-sparse retrieval (§2.2) + edge-list graph encoding (Conclusion)."""

import numpy as np

from repro.core import (DynamicIndex, GraphStore, Warren, add_json,
                        index_document, score_bm25)
from repro.core.sparse import (index_sparse_vector, score_hybrid,
                               score_sparse)


def test_sparse_vectors_coexist_with_bm25():
    w = Warren(DynamicIndex())
    docs = ["the quick brown fox", "lazy dogs sleep all day",
            "foxes hunt at night", "markets rallied on tech news"]
    extents = []
    with w:
        w.transaction()
        for i, d in enumerate(docs):
            extents.append(index_document(w, d, docid=str(i)))
        remap = w.commit()
    extents = [(remap(a), remap(b)) for a, b in extents]

    # learned-sparse weights added LATER, separate transaction (§5 model)
    vecs = [{"fox": 2.1, "animal": 1.3},          # expansion terms!
            {"dog": 1.8, "animal": 1.2, "rest": 0.7},
            {"fox": 1.9, "hunt": 1.5, "animal": 0.9},
            {"finance": 2.2, "market": 1.7}]
    with w:
        w.transaction()
        for ext, vec in zip(extents, vecs):
            index_sparse_vector(w, ext, vec, method="splade")
        w.commit()

    with w:
        # sparse-only: "animal" matches docs 0,1,2 via expansion
        top = score_sparse(w, {"animal": 1.0}, k=4)
        assert len(top) == 3
        assert {d for d, _ in top} == {e[0] for e in extents[:3]}
        # both methods over one index; hybrid fuses them
        bm = score_bm25(w, "fox", k=2)
        hy = score_hybrid(w, "fox", {"fox": 1.0, "animal": 0.5}, k=3)
        assert bm and hy
        assert hy[0][0] in (extents[0][0], extents[2][0])


def test_edge_list_encoding_no_dangling_refs():
    w = Warren(DynamicIndex())
    g = GraphStore(w)
    with w:
        w.transaction()
        a = g.add_node({"name": "a"})
        b = g.add_node({"name": "b"})
        c = g.add_node({"name": "c"})
        remap = w.commit()
    a, b, c = [(remap(x[0]), remap(x[1])) for x in (a, b, c)]
    with w:
        w.transaction()
        g.add_out_edges("@follows", a, [b[0], c[0]])
        w.commit()
    with w:
        assert sorted(g.out_edges("@follows", a)) == sorted([b[0], c[0]])
    # delete node c: its edge entries vanish with it (the encoding's point)
    with w:
        w.transaction()
        w.erase(*c)
        w.commit()
    with w:
        assert g.out_edges("@follows", a) == [b[0]]

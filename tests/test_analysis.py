"""Tier-1: the concurrency contract checker + runtime lock witness.

Static half: fixture modules under ``tests/fixtures/analysis/`` with a
known lock-order inversion, a blocking-call-under-lock, a
metric-contract violation, and a clean module — asserting the *exact*
finding id sets.  Shipped-tree half: ``repro.analysis`` over ``src/``
must be clean under the checked-in hierarchy/suppressions, and must see
the checkpoint path's rebalance→group_write discipline.  Runtime half:
a LockWitness must catch a seeded AB/BA inversion across two threads.
"""

import ast
import os
import threading
from pathlib import Path

import pytest

from repro import obs
from repro.analysis import (Catalog, Hierarchy, Suppressions,
                            SuppressionError, run_analysis)
from repro.analysis import toml_lite
from repro.analysis.callgraph import CallGraph
from repro.analysis.contracts import analyze_contracts
from repro.analysis.driver import main
from repro.analysis.lockmap import build_lockmap

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "analysis"


def ids(report):
    return sorted(f.id for f in report.active)


# --------------------------------------------------------------------- #
# toml_lite + config plumbing
# --------------------------------------------------------------------- #
def test_toml_lite_roundtrip(tmp_path):
    p = tmp_path / "t.toml"
    p.write_text(
        '# comment\n[a]\nx = 1\ny = "two"\nz = [1, 2, 3]\n'
        'flag = true\n[locks."Dotted.name"]\nrank = 7\n'
        '[[suppress]]\nid = "k"\nreason = "because"\n')
    doc = toml_lite.load(str(p))
    assert doc["a"] == {"x": 1, "y": "two", "z": [1, 2, 3], "flag": True}
    assert doc["locks"]["Dotted.name"]["rank"] == 7
    assert doc["suppress"] == [{"id": "k", "reason": "because"}]


def test_suppressions_require_reason(tmp_path):
    p = tmp_path / "s.toml"
    p.write_text('[[suppress]]\nid = "some:finding"\n')
    with pytest.raises(SuppressionError):
        Suppressions.load(str(p))


def test_suppressions_reject_wildcards(tmp_path):
    p = tmp_path / "s.toml"
    p.write_text('[[suppress]]\nid = "blocking-*"\nreason = "all of it"\n')
    with pytest.raises(SuppressionError):
        Suppressions.load(str(p))


def test_catalog_parses_markdown_tables():
    text = (
        "| metric | type | labels | emitted from |\n"
        "|---|---|---|---|\n"
        "| `ops_total` | counter | `op`, `shard` (id) | here |\n"
        "\n"
        "| span | emitted from |\n"
        "|---|---|\n"
        "| `scatter` | router |\n")
    cat = Catalog.parse(text)
    assert cat.metrics == {"ops_total": {"op", "shard"}}
    assert cat.spans == {"scatter"}


def test_hierarchy_rejects_duplicate_ranks(tmp_path):
    p = tmp_path / "h.toml"
    p.write_text("[locks.a]\nrank = 1\n[locks.b]\nrank = 1\n")
    with pytest.raises(ValueError):
        Hierarchy.load(str(p))


# --------------------------------------------------------------------- #
# fixture modules: exact finding sets
# --------------------------------------------------------------------- #
def test_fixture_inversion_detects_cycle():
    rep = run_analysis([str(FIXTURES / "fix_inversion.py")],
                       use_defaults=False)
    assert ids(rep) == [
        "lock-cycle:Inverted._alpha->Inverted._beta->Inverted._alpha"]
    assert rep.exit_code == 1


def test_fixture_inversion_hierarchy_named(tmp_path):
    # with declared ranks the same fixture also yields the rank violation
    h = tmp_path / "h.toml"
    h.write_text('[locks."Inverted._alpha"]\nrank = 1\n'
                 '[locks."Inverted._beta"]\nrank = 2\n')
    rep = run_analysis([str(FIXTURES / "fix_inversion.py")],
                       hierarchy_path=str(h), use_defaults=False)
    assert ids(rep) == [
        "lock-cycle:Inverted._alpha->Inverted._beta->Inverted._alpha",
        "lock-hierarchy:Inverted._beta->Inverted._alpha"]


def test_fixture_blocking_under_hot_lock(tmp_path):
    h = tmp_path / "h.toml"
    h.write_text('[locks."HotPath._lock"]\nrank = 1\nhot = true\n')
    rep = run_analysis([str(FIXTURES / "fix_blocking.py")],
                       hierarchy_path=str(h), use_defaults=False)
    assert ids(rep) == [
        "blocking-under-lock:HotPath._lock:HotPath.flush:os.fsync",
        "blocking-under-lock:HotPath._lock:HotPath.save:os.fsync"]


def test_fixture_blocking_quiet_when_not_hot(tmp_path):
    h = tmp_path / "h.toml"
    h.write_text('[locks."HotPath._lock"]\nrank = 1\n')
    rep = run_analysis([str(FIXTURES / "fix_blocking.py")],
                       hierarchy_path=str(h), use_defaults=False)
    assert ids(rep) == []


def test_fixture_metric_contracts(tmp_path):
    cat = tmp_path / "arch.md"
    cat.write_text("| metric | type | labels | emitted from |\n"
                   "|---|---|---|---|\n"
                   "| `fixture_ops_total` | counter | `op` | fixture |\n")
    rep = run_analysis([str(FIXTURES / "fix_metrics.py")],
                       catalog_path=str(cat), use_defaults=False)
    assert ids(rep) == [
        "metric-labels:fixture_ops_total:Meter.count",
        "undeclared-metric:fixture_undeclared_ms"]


def test_fixture_clean_has_no_findings(tmp_path):
    h = tmp_path / "h.toml"
    h.write_text('[locks."Clean._outer"]\nrank = 1\nhot = true\n'
                 '[locks."Clean._inner"]\nrank = 2\n')
    cat = tmp_path / "arch.md"
    cat.write_text("| metric | type | labels | emitted from |\n"
                   "|---|---|---|---|\n"
                   "| `fixture_ops_total` | counter | `op` | fixture |\n")
    rep = run_analysis([str(FIXTURES / "fix_clean.py")],
                       hierarchy_path=str(h), catalog_path=str(cat),
                       use_defaults=False)
    assert ids(rep) == []
    assert rep.exit_code == 0
    assert ("Clean._outer", "Clean._inner") in rep.lock_order.edges


def test_cli_exit_codes(capsys):
    assert main([str(FIXTURES / "fix_inversion.py"), "--no-defaults"]) == 1
    assert "lock-cycle" in capsys.readouterr().out
    assert main([str(FIXTURES / "fix_clean.py"), "--no-defaults"]) == 0


# --------------------------------------------------------------------- #
# guard lint (inline hot-path module)
# --------------------------------------------------------------------- #
def _contract_findings(code, module="x/train/serve.py", catalog=None):
    modules = {module: ast.parse(code)}
    graph = CallGraph(modules, build_lockmap(modules))
    return analyze_contracts(graph, catalog or Catalog())


def test_unguarded_metric_in_hot_module():
    found = _contract_findings(
        "import repro.obs as obs\n"
        "def handle(n):\n"
        "    obs.registry().counter('reqs_total').inc()\n")
    assert [f.id for f in found] == ["unguarded-metric:reqs_total:handle"]


def test_guarded_variants_pass():
    found = _contract_findings(
        "import repro.obs as obs\n"
        "def direct(n):\n"
        "    reg = obs.registry()\n"
        "    if reg.enabled:\n"
        "        reg.counter('reqs_total').inc()\n"
        "def early(n):\n"
        "    reg = obs.registry()\n"
        "    if not reg.enabled:\n"
        "        return\n"
        "    reg.counter('reqs_total').inc()\n"
        "def derived(n):\n"
        "    observe = obs.registry().enabled and n > 0\n"
        "    if observe:\n"
        "        obs.registry().counter('reqs_total').inc()\n")
    assert [f.id for f in found] == []


def test_undeclared_span():
    cat = Catalog.parse("| span | emitted from |\n|---|---|\n"
                        "| `scatter` | router |\n")
    found = _contract_findings(
        "import repro.obs as obs\n"
        "def go():\n"
        "    with obs.span('mystery'):\n"
        "        pass\n"
        "    with obs.span('scatter'):\n"
        "        pass\n", catalog=cat)
    assert [f.id for f in found] == ["undeclared-span:mystery"]


# --------------------------------------------------------------------- #
# the shipped tree
# --------------------------------------------------------------------- #
def test_shipped_tree_is_clean():
    rep = run_analysis([str(REPO / "src")])
    assert ids(rep) == []
    assert rep.exit_code == 0
    assert not rep.unused_suppressions
    # every suppression carries a justification
    assert all(reason for _, reason in rep.suppressed)


def test_checkpoint_discipline_is_visible():
    """The acceptance path: checkpoint takes the rebalance lock, then
    every group write lock ascending — the analyzer must see the edge
    and the declared hierarchy must call it legal."""
    rep = run_analysis([str(REPO / "src")])
    edges = rep.lock_order.edges
    assert ("rebalance", "group_write") in edges
    h = Hierarchy.load(str(REPO / "analysis" / "lock_hierarchy.toml"))
    assert h.rank("rebalance") < h.rank("group_write")
    assert h.multi("group_write") == "ascending"
    # and the WAL sits below the group locks, as the 2PC design requires
    assert ("group_write", "wal") in edges
    assert h.rank("group_write") < h.rank("wal")


# --------------------------------------------------------------------- #
# runtime lock witness
# --------------------------------------------------------------------- #
def _in_thread(fn):
    err = []

    def run():
        try:
            fn()
        except BaseException as e:          # pragma: no cover
            err.append(e)

    t = threading.Thread(target=run)
    t.start()
    t.join()
    assert not err


def test_witness_catches_ab_ba_inversion():
    """Seeded AB/BA across two threads — neither deadlocks (they run
    sequentially), but the witness must still convict the pair."""
    a = obs.ProfiledLock("fix_a")
    b = obs.ProfiledLock("fix_b")
    w = obs.install_witness(obs.LockWitness())
    try:
        def t1():
            with a:
                with b:
                    pass

        def t2():
            with b:
                with a:
                    pass

        _in_thread(t1)
        assert w.violations() == []         # A→B alone is fine
        _in_thread(t2)
        assert any("cycle" in v for v in w.violations())
        with pytest.raises(obs.LockOrderViolation):
            w.check()
    finally:
        obs.uninstall_witness()


def test_witness_hierarchy_and_ascending():
    w = obs.LockWitness(ranks={"outer": 1, "inner": 2},
                        multi={"grp": "ascending"})
    w.note_acquire("inner", None, 1)
    w.note_acquire("outer", None, 2)        # rank inversion
    w.note_release("outer", 2)
    w.note_release("inner", 1)
    w.note_acquire("grp", 2, 3)
    w.note_acquire("grp", 1, 4)             # descending order key
    w.note_release("grp", 4)
    w.note_release("grp", 3)
    v = w.violations()
    assert any("hierarchy" in x for x in v)
    assert any("ascending-order" in x for x in v)


def test_witness_allows_clean_orders():
    w = obs.LockWitness(ranks={"outer": 1, "inner": 2},
                        multi={"grp": "ascending", "re": "reentrant"})
    w.note_acquire("outer", None, 1)
    w.note_acquire("inner", None, 2)
    w.note_release("inner", 2)
    w.note_release("outer", 1)
    w.note_acquire("grp", 1, 3)
    w.note_acquire("grp", 2, 4)             # ascending: legal
    w.note_release("grp", 4)
    w.note_release("grp", 3)
    w.note_acquire("re", None, 5)
    w.note_acquire("re", None, 5)           # same instance: reentrant
    w.note_release("re", 5)
    w.note_release("re", 5)
    assert w.violations() == []
    w.check()                               # must not raise


def test_witness_profiledlock_overhead_hook_is_inert():
    """With no witness installed a ProfiledLock round-trip must work and
    record nothing anywhere."""
    assert obs.witness_active() is None
    lk = obs.ProfiledLock("inert")
    with lk:
        assert lk.locked()
    assert not lk.locked()


def test_group_write_order_key_is_group_id():
    from repro.dist.shard_router import ReplicaGroup
    from repro.core.index import DynamicIndex
    g = ReplicaGroup(3, [DynamicIndex()])
    assert g.write_lock.order_key == 3
    assert g.write_lock.name == "group_write"

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.annotation import AnnotationList, reduce_minimal
from repro.core.vectorized import PAD, pack
from repro.kernels import (bm25_blockmax_topk, bm25_topk_ref,
                           embedding_bag_padded, embedding_bag_ref,
                           gqa_decode, gqa_decode_ref, interval_join)
from repro.kernels.interval_join.ref import (contained_in_mask_ref,
                                             containing_mask_ref)


def random_gc_list(rng, n, span=10_000):
    starts = np.sort(rng.choice(span, size=n, replace=False)).astype(np.int64)
    ends = starts + rng.integers(0, 50, size=n)
    lst = reduce_minimal(starts, ends, np.zeros(n))
    return lst


# ------------------------------------------------------------------ #
@pytest.mark.parametrize("na,nb", [(16, 16), (100, 37), (513, 257), (1000, 3)])
@pytest.mark.parametrize("mode", ["contained_in", "containing"])
def test_interval_join_sweep(na, nb, mode):
    rng = np.random.default_rng(na * 1000 + nb + len(mode))
    A = random_gc_list(rng, na)
    B = random_gc_list(rng, nb)
    a_s, a_e, _ = pack(A.starts, A.ends)
    b_s, b_e, _ = pack(B.starts, B.ends)
    got = interval_join(a_s, a_e, b_s, b_e, mode=mode, use_pallas=True)
    ref_fn = contained_in_mask_ref if mode == "contained_in" else containing_mask_ref
    want = ref_fn(a_s, a_e, b_s, b_e)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_interval_join_matches_lazy_engine():
    from repro.core import gcl
    rng = np.random.default_rng(7)
    A = random_gc_list(rng, 200, span=2000)
    B = random_gc_list(rng, 50, span=2000)
    node = gcl.ContainedIn(gcl.Term(A), gcl.Term(B))
    lazy = {(p, q) for p, q, _ in node.solutions()}
    a_s, a_e, _ = pack(A.starts, A.ends)
    b_s, b_e, _ = pack(B.starts, B.ends)
    mask = np.asarray(interval_join(a_s, a_e, b_s, b_e, mode="contained_in"))
    got = {(int(A.starts[i]), int(A.ends[i])) for i in np.flatnonzero(mask[:len(A)])}
    assert got == lazy


# ------------------------------------------------------------------ #
@pytest.mark.parametrize("t,nb,bs,k", [(4, 8, 128, 10), (8, 32, 128, 25),
                                       (2, 4, 256, 5), (16, 16, 128, 100)])
def test_bm25_blockmax_sweep(t, nb, bs, k):
    rng = np.random.default_rng(t * 100 + nb)
    # sparse impacts: ~10% fill
    impacts = rng.random((t, nb, bs), dtype=np.float32)
    impacts *= rng.random((t, nb, bs)) < 0.1
    block_max = impacts.max(axis=2)
    got_s, got_i = bm25_blockmax_topk(jnp.asarray(impacts),
                                      jnp.asarray(block_max), k=k)
    want_s, want_i = bm25_topk_ref(jnp.asarray(impacts), k)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-5, atol=1e-6)
    # ids may differ on exact ties; scores must match as multisets
    assert set(np.asarray(got_i)[np.asarray(got_s) > 0]) == \
           set(np.asarray(want_i)[np.asarray(want_s) > 0])


def test_bm25_blockmax_prunes():
    from repro.kernels import pruned_fraction
    rng = np.random.default_rng(0)
    t, nb, bs = 4, 64, 128
    impacts = rng.random((t, nb, bs), dtype=np.float32)
    impacts *= rng.random((t, nb, bs)) < 0.05
    # a few hot blocks
    impacts[:, :2, :] *= 10
    block_max = impacts.max(axis=2)
    s, _ = bm25_blockmax_topk(jnp.asarray(impacts), jnp.asarray(block_max), k=5)
    theta = float(s[-1])
    frac = float(pruned_fraction(jnp.asarray(block_max), theta))
    assert frac > 0.3, f"expected meaningful pruning, got {frac}"


# ------------------------------------------------------------------ #
# degenerate shapes: the failure modes happy-path sweeps never reach
# ------------------------------------------------------------------ #
def _bm25_parity(impacts, k):
    """Pallas vs oracle: exact positive scores, tie-tolerant ids."""
    impacts = jnp.asarray(impacts)
    got_s, got_i = bm25_blockmax_topk(impacts, impacts.max(axis=2), k=k)
    want_s, want_i = bm25_topk_ref(impacts, k)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-5, atol=1e-6)
    assert set(np.asarray(got_i)[np.asarray(got_s) > 0]) == \
           set(np.asarray(want_i)[np.asarray(want_s) > 0])


def test_bm25_blockmax_empty_posting_list():
    """All-zero impacts (no term hits anything): no -inf junk, all zeros."""
    _bm25_parity(np.zeros((2, 4, 128), np.float32), k=5)


def test_bm25_blockmax_single_element_block():
    """[1, 1, 1]: the θ pre-pass scores the only doc exactly, so the block
    sits at ub == θ — it must be swept, not pruned (regression: the strict
    ub > θ predicate dropped the true top-1 here)."""
    imp = np.zeros((1, 1, 1), np.float32)
    imp[0, 0, 0] = 2.5
    _bm25_parity(imp, k=1)


def test_bm25_blockmax_theta_tie_boundary():
    """Several blocks tied at exactly ub == θ: every tied block must be
    scored so the returned score multiset matches the oracle."""
    imp = np.zeros((1, 4, 8), np.float32)
    imp[0, :, 3] = 1.0                   # one doc of score 1.0 per block
    _bm25_parity(imp, k=4)


@pytest.mark.parametrize("t,nb,bs,k", [(1, 1, 100, 3), (3, 5, 100, 7),
                                       (2, 3, 7, 4)])
def test_bm25_blockmax_block_length_not_tile_divisible(t, nb, bs, k):
    """BS not a multiple of the 128-lane tile (interpret-mode contract)."""
    rng = np.random.default_rng(t * 31 + nb)
    imp = rng.random((t, nb, bs), dtype=np.float32)
    imp *= rng.random((t, nb, bs)) < 0.2
    _bm25_parity(imp.astype(np.float32), k=min(k, nb * bs))


def test_bm25_blockmax_k_exceeds_positive_docs():
    """Top-k spilling past the last positive doc pads with zeros, like the
    exhaustive oracle — never -inf."""
    imp = np.zeros((2, 2, 8), np.float32)
    imp[0, 0, 1] = 3.0
    imp[1, 1, 4] = 1.5
    impacts = jnp.asarray(imp)
    got_s, _ = bm25_blockmax_topk(impacts, impacts.max(axis=2), k=10)
    want_s, _ = bm25_topk_ref(impacts, 10)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=1e-6, atol=1e-6)
    assert np.isfinite(np.asarray(got_s)).all()


@pytest.mark.parametrize("mode", ["contained_in", "containing"])
def test_interval_join_empty_lists(mode):
    """pack() of an empty GC-list yields a single PAD entry; the join must
    return an all-zero mask on either (or both) sides."""
    empty = pack(np.array([], np.int64), np.array([], np.int64))
    one = pack(np.array([5], np.int64), np.array([9], np.int64))
    for a, b in [(empty, one), (one, empty), (empty, empty)]:
        got = interval_join(a[0], a[1], b[0], b[1], mode=mode,
                            use_pallas=True)
        ref_fn = (contained_in_mask_ref if mode == "contained_in"
                  else containing_mask_ref)
        want = ref_fn(a[0], a[1], b[0], b[1])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert not np.asarray(got).any()


@pytest.mark.parametrize("a,b,contained,containing", [
    ((5, 9), (4, 10), 1, 0),      # A strictly inside B
    ((4, 10), (5, 9), 0, 1),      # A strictly contains B
    ((5, 9), (5, 9), 1, 1),       # identical intervals contain each other
    ((5, 9), (20, 30), 0, 0),     # disjoint
])
def test_interval_join_single_element(a, b, contained, containing):
    a_s, a_e, _ = pack(np.array([a[0]], np.int64), np.array([a[1]], np.int64))
    b_s, b_e, _ = pack(np.array([b[0]], np.int64), np.array([b[1]], np.int64))
    got_in = interval_join(a_s, a_e, b_s, b_e, mode="contained_in")
    got_on = interval_join(a_s, a_e, b_s, b_e, mode="containing")
    assert int(np.asarray(got_in)[0]) == contained
    assert int(np.asarray(got_on)[0]) == containing


@pytest.mark.parametrize("na,nb,tile", [(13, 5, 8), (20, 17, 8), (1, 9, 8),
                                        (257, 3, 128)])
@pytest.mark.parametrize("mode", ["contained_in", "containing"])
def test_interval_join_list_length_not_tile_divisible(na, nb, tile, mode):
    """Lengths that leave a partial final tile: the pad entries must never
    join, and multi-tile accumulation must match the oracle exactly."""
    from repro.kernels.interval_join.kernel import interval_join_pallas
    rng = np.random.default_rng(na * 100 + nb + tile)
    A = random_gc_list(rng, na, span=4000)
    B = random_gc_list(rng, nb, span=4000)
    a_s, a_e, _ = pack(A.starts, A.ends)
    b_s, b_e, _ = pack(B.starts, B.ends)
    got = interval_join_pallas(a_s, a_e, b_s, b_e, mode=mode,
                               tile_a=tile, tile_b=tile)
    ref_fn = (contained_in_mask_ref if mode == "contained_in"
              else containing_mask_ref)
    want = ref_fn(a_s, a_e, b_s, b_e)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------------ #
@pytest.mark.parametrize("b,hkv,g,d,s", [(2, 2, 4, 64, 256), (1, 4, 1, 128, 512),
                                         (2, 1, 8, 128, 300), (4, 2, 2, 64, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gqa_decode_sweep(b, hkv, g, d, s, dtype):
    rng = np.random.default_rng(b * 100 + s)
    q = jnp.asarray(rng.standard_normal((b, hkv, g, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    length = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    got = gqa_decode(q, k, v, length, use_pallas=True, block_size=128)
    want = gqa_decode_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), length)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=tol, atol=tol)


# ------------------------------------------------------------------ #
@pytest.mark.parametrize("v,d,b,l", [(100, 32, 8, 5), (1000, 64, 16, 20),
                                     (64, 128, 4, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_embedding_bag_sweep(v, d, b, l, dtype):
    rng = np.random.default_rng(v + d)
    table = jnp.asarray(rng.standard_normal((v, d)), dtype)
    idx = jnp.asarray(rng.integers(0, v, size=(b, l)), jnp.int32)
    w = jnp.asarray((rng.random((b, l)) < 0.8).astype(np.float32))
    got_pallas = embedding_bag_padded(table, idx, w, use_pallas=True)
    got_jnp = embedding_bag_padded(table, idx, w, use_pallas=False)
    # oracle: flat segment-sum formulation
    seg = np.repeat(np.arange(b), l)
    want = embedding_bag_ref(table, idx.reshape(-1), jnp.asarray(seg), b,
                             weights=w.reshape(-1))
    np.testing.assert_allclose(np.asarray(got_jnp), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_pallas), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

"""v2 block-run format robustness: fuzzed corruption is always *typed*,
and the v1 four-file layout stays readable (and upgradeable) forever.

The corruption contract: any truncation or bit flip of ``run.aix2``
either surfaces as :class:`repro.core.runfile.RunCorruption` (from open
or from any later lazy block read) or leaves every read bit-identical to
the pristine run (a flip in dead bytes, e.g. block zero-padding) — the
reader never returns garbage and never dies with an untyped error.

Back-compat: ``tests/fixtures/v1_run`` is a committed v1 layout (written
by the pre-block writer).  It must keep opening read-only with exact
contents, and one ``merge_runs`` pass must upgrade it to v2 losslessly —
that migration (open v1, compact, serve v2) is the only upgrade story.
"""

import os
import shutil

import numpy as np
import pytest

from repro.core import DynamicIndex, Warren, index_document, score_bm25
from repro.core.runfile import RUN_FILE, RunCorruption
from repro.core.static import (StaticIndex, _write_static_v1, merge_runs,
                               write_static)

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "v1_run")


def _build_index(n=12, erased=("d3",)):
    idx = DynamicIndex()
    w = Warren(idx)
    with w:
        w.transaction()
        for i in range(n):
            index_document(w, f"fuzz target doc {i} shared words fox",
                           docid=f"d{i}")
        w.commit()
    for d in erased:
        with w:
            lst = w.annotations("docid:" + d)
            w.transaction()
            w.erase(int(lst.starts[0]), int(lst.ends[0]))
            w.commit()
    return idx


def _full_read(directory):
    """Every read surface the run offers, as one comparable value."""
    si = StaticIndex(directory)
    try:
        out = []
        docs = si.annotations(":")
        for i in range(len(docs)):
            p, q = int(docs.starts[i]), int(docs.ends[i])
            out.append((p, q, si.translate(p, q), tuple(si.tokens(p, q))))
        for f in sorted(si.features()):
            lst = si.annotations(f)
            out.append((f, lst.starts.tolist(), lst.ends.tolist(),
                        lst.values.tolist()))
        er = si.erased
        out.append(("erased", er.starts.tolist(), er.ends.tolist()))
        out.append(("bm25", [(d, round(s, 12))
                             for d, s in score_bm25(si, "shared fox", k=5)]))
        return out
    finally:
        si.close()


@pytest.fixture(scope="module")
def pristine(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("fmt") / "run")
    write_static(_build_index(), d)
    return d, _full_read(d)


def _corrupt_copy(pristine_dir, tmp, name, mutate):
    d = str(tmp / name)
    shutil.copytree(pristine_dir, d)
    path = os.path.join(d, RUN_FILE)
    with open(path, "rb") as fh:
        raw = bytearray(fh.read())
    raw = mutate(raw)
    with open(path, "wb") as fh:
        fh.write(bytes(raw))
    return d


def test_truncation_at_any_point_is_typed_corruption(pristine, tmp_path):
    d, _ = pristine
    size = os.path.getsize(os.path.join(d, RUN_FILE))
    rng = np.random.default_rng(0)
    cuts = sorted({0, 1, size // 2, size - 1, size - 8, size - 24}
                  | {int(x) for x in rng.integers(0, size, 20)})
    for cut in cuts:
        work = _corrupt_copy(d, tmp_path, f"t{cut}", lambda b: b[:cut])
        # truncation always removes the trailer -> open itself must fail
        with pytest.raises(RunCorruption):
            StaticIndex(work)


def test_single_bit_flips_never_produce_garbage(pristine, tmp_path):
    d, want = pristine
    size = os.path.getsize(os.path.join(d, RUN_FILE))
    rng = np.random.default_rng(1)
    offsets = sorted({0, size - 1, size - 10}
                     | {int(x) for x in rng.integers(0, size, 40)})
    survived = corrupted = 0
    for off in offsets:
        bit = int(rng.integers(0, 8))

        def flip(b, off=off, bit=bit):
            b[off] ^= 1 << bit
            return b

        work = _corrupt_copy(d, tmp_path, f"b{off}_{bit}", flip)
        try:
            got = _full_read(work)
        except RunCorruption:
            corrupted += 1
        else:
            # a flip in dead bytes (block padding) is allowed ONLY if every
            # read stays bit-identical to the pristine run
            assert got == want, f"garbage after flipping bit {bit} @ {off}"
            survived += 1
    assert corrupted > 0        # the fuzz actually hit live bytes


def test_extra_garbage_file_in_run_dir_is_ignored(pristine, tmp_path):
    d, want = pristine
    work = str(tmp_path / "extra")
    shutil.copytree(d, work)
    with open(os.path.join(work, "stray.tmp"), "wb") as fh:
        fh.write(b"leftover from a crashed writer")
    assert _full_read(work) == want


def test_empty_or_alien_file_is_typed_corruption(tmp_path):
    d = str(tmp_path / "alien")
    os.makedirs(d)
    with open(os.path.join(d, RUN_FILE), "wb") as fh:
        fh.write(b"not a block run at all")
    with pytest.raises(RunCorruption):
        StaticIndex(d)
    with pytest.raises(RunCorruption):
        StaticIndex(str(tmp_path / "missing"))   # no layout at all


# ------------------------------------------------------------------ #
# v1 back-compat: the committed fixture opens forever
# ------------------------------------------------------------------ #
def test_v1_fixture_opens_read_only():
    si = StaticIndex(FIXTURE)
    try:
        docs = si.annotations(":")
        assert len(docs) == 5                 # 6 written, d2 erased
        texts = {si.translate(int(docs.starts[i]), int(docs.ends[i]))
                 for i in range(len(docs))}
        assert texts == {f"fixture doc {i} frozen in the v1 layout"
                         for i in (0, 1, 3, 4, 5)}
        assert len(si.annotations("docid:d2")) == 0     # erased stays erased
        assert len(si.erased) == 1
        top = score_bm25(si, "fixture frozen", k=3)
        assert len(top) == 3
    finally:
        si.close()


def test_v1_fixture_upgrades_to_v2_via_merge(tmp_path):
    out = str(tmp_path / "v2")
    merge_runs([FIXTURE], out)
    assert os.path.exists(os.path.join(out, RUN_FILE))
    v1 = StaticIndex(FIXTURE)
    v2 = StaticIndex(out)
    try:
        for f in (":", "docid:d0", "docid:d2", "fixture"):
            a, b = v1.annotations(f), v2.annotations(f)
            np.testing.assert_array_equal(a.starts, b.starts)
            np.testing.assert_array_equal(a.ends, b.ends)
            np.testing.assert_array_equal(a.values, b.values)
        docs = v1.annotations(":")
        for i in range(len(docs)):
            p, q = int(docs.starts[i]), int(docs.ends[i])
            assert v1.translate(p, q) == v2.translate(p, q)
        np.testing.assert_array_equal(v1.erased.starts, v2.erased.starts)
        np.testing.assert_array_equal(v1.erased.ends, v2.erased.ends)
    finally:
        v1.close()
        v2.close()


def test_v1_writer_and_v2_writer_agree(tmp_path):
    """The retained v1 writer and the v2 writer produce bit-identical
    read surfaces for the same index (the fixture generator stays
    honest)."""
    idx = _build_index(n=8)
    d1, d2 = str(tmp_path / "v1"), str(tmp_path / "v2")
    _write_static_v1(idx, d1)
    write_static(idx, d2)
    assert os.path.exists(os.path.join(d1, "meta.msgpack"))
    assert os.path.exists(os.path.join(d2, RUN_FILE))
    assert _full_read(d1) == _full_read(d2)

"""Cross-pod compressed reduction inside shard_map (single-device mesh:
axis size 1 keeps it runnable here; the collective path is identical)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.dist.compression import (cross_pod_reduce_compressed,
                                    init_residual)


def test_cross_pod_reduce_in_shard_map():
    mesh = jax.make_mesh((1,), ("pod",))
    grads = {"w": jnp.asarray(np.random.default_rng(0)
                              .standard_normal((16, 16)) * 1e-3,
                              jnp.float32)}
    residual = init_residual(grads)

    def fn(g, r):
        return cross_pod_reduce_compressed(g, r, axis_name="pod")

    out, new_res = shard_map(fn, mesh=mesh,
                             in_specs=(P(), P()), out_specs=(P(), P()))(
        grads, residual)
    # with axis size 1, reduce == dequantize(quantize(g)); error feedback
    # carries the rounding error
    err = np.asarray(out["w"]) - np.asarray(grads["w"])
    step = float(jnp.abs(grads["w"]).max()) / 127.0
    assert np.abs(err).max() <= step
    np.testing.assert_allclose(np.asarray(new_res["w"]), -err, atol=1e-9)

"""GCL operator algebra vs a brute-force oracle (the core paper machinery)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.annotation import INF, NINF, AnnotationList, reduce_minimal
from repro.core import gcl


# --------------------------------------------------------------------- #
# brute-force oracle
# --------------------------------------------------------------------- #
def contains(outer, inner):
    return outer[0] <= inner[0] and inner[1] <= outer[1]


def brute_contained_in(A, B):
    return [a for a in A if any(contains(b, a) for b in B)]


def brute_containing(A, B):
    return [a for a in A if any(contains(a, b) for b in B)]


def brute_not_contained_in(A, B):
    return [a for a in A if not any(contains(b, a) for b in B)]


def brute_not_containing(A, B):
    return [a for a in A if not any(contains(a, b) for b in B)]


def g_reduce(intervals):
    ivs = sorted(set(intervals))
    return [a for a in ivs
            if not any(b != a and contains(a, b) for b in ivs)]


def brute_both_of(A, B):
    return g_reduce([(min(a[0], b[0]), max(a[1], b[1])) for a in A for b in B])


def brute_one_of(A, B):
    return g_reduce([a[:2] for a in A] + [b[:2] for b in B])


def brute_followed_by(A, B):
    return g_reduce([(a[0], b[1]) for a in A for b in B if a[1] < b[0]])


def make_gc_list(intervals_with_values):
    if not intervals_with_values:
        return AnnotationList.empty()
    s = np.array([i[0] for i in intervals_with_values], dtype=np.int64)
    e = np.array([i[1] for i in intervals_with_values], dtype=np.int64)
    v = np.array([i[2] if len(i) > 2 else 0.0 for i in intervals_with_values])
    return reduce_minimal(s, e, v)


gc_list_strategy = st.lists(
    st.tuples(st.integers(0, 40), st.integers(0, 12)).map(lambda t: (t[0], t[0] + t[1])),
    max_size=14,
)

OPS = {
    "contained_in": (gcl.ContainedIn, brute_contained_in),
    "containing": (gcl.Containing, brute_containing),
    "not_contained_in": (gcl.NotContainedIn, brute_not_contained_in),
    "not_containing": (gcl.NotContaining, brute_not_containing),
    "both_of": (gcl.BothOf, brute_both_of),
    "one_of": (gcl.OneOf, brute_one_of),
    "followed_by": (gcl.FollowedBy, brute_followed_by),
}


def check_op(name, a_ivs, b_ivs):
    node_cls, brute = OPS[name]
    A = make_gc_list(a_ivs)
    B = make_gc_list(b_ivs)
    a_min = [(int(p), int(q)) for p, q, _ in A]
    b_min = [(int(p), int(q)) for p, q, _ in B]
    expected = sorted(set(i[:2] for i in brute(a_min, b_min)))

    node = node_cls(gcl.Term(A), gcl.Term(B))
    got = [(p, q) for p, q, _ in node.solutions()]
    assert got == expected, f"{name}: solutions {got} != {expected}"

    # access-method pointwise checks (fresh node per probe: no cursor reuse)
    for k in range(-2, 60):
        n = node_cls(gcl.Term(A), gcl.Term(B))
        t = n.tau(k)
        exp = next((s for s in expected if s[0] >= k), None)
        assert (t[:2] == exp if exp else t[1] >= INF), f"{name}.tau({k})={t} exp={exp}"

        n = node_cls(gcl.Term(A), gcl.Term(B))
        r = n.rho(k)
        exp = next((s for s in expected if s[1] >= k), None)
        assert (r[:2] == exp if exp else r[1] >= INF), f"{name}.rho({k})={r} exp={exp}"

        n = node_cls(gcl.Term(A), gcl.Term(B))
        tb = n.tau_b(k)
        exp = next((s for s in reversed(expected) if s[0] <= k), None)
        assert (tb[:2] == exp if exp else tb[0] <= NINF), f"{name}.tau_b({k})={tb} exp={exp}"

        n = node_cls(gcl.Term(A), gcl.Term(B))
        rb = n.rho_b(k)
        exp = next((s for s in reversed(expected) if s[1] <= k), None)
        assert (rb[:2] == exp if exp else rb[0] <= NINF), f"{name}.rho_b({k})={rb} exp={exp}"


@pytest.mark.parametrize("name", list(OPS))
@settings(max_examples=120, deadline=None)
@given(a=gc_list_strategy, b=gc_list_strategy)
def test_operator_matches_brute_force(name, a, b):
    check_op(name, a, b)


@settings(max_examples=60, deadline=None)
@given(a=gc_list_strategy, b=gc_list_strategy, c=gc_list_strategy)
def test_nested_operator_composition(a, b, c):
    """(A △ B) ⊲ C and (A ▽ B) ◇ C against oracle composition."""
    A, B, C = make_gc_list(a), make_gc_list(b), make_gc_list(c)
    a_min = [(int(p), int(q)) for p, q, _ in A]
    b_min = [(int(p), int(q)) for p, q, _ in B]
    c_min = [(int(p), int(q)) for p, q, _ in C]

    node = gcl.ContainedIn(gcl.BothOf(gcl.Term(A), gcl.Term(B)), gcl.Term(C))
    got = [(p, q) for p, q, _ in node.solutions()]
    expected = sorted(set(brute_contained_in(brute_both_of(a_min, b_min), c_min)))
    assert got == expected

    node = gcl.FollowedBy(gcl.OneOf(gcl.Term(A), gcl.Term(B)), gcl.Term(C))
    got = [(p, q) for p, q, _ in node.solutions()]
    expected = sorted(set(brute_followed_by(brute_one_of(a_min, b_min), c_min)))
    assert got == expected


@settings(max_examples=60, deadline=None)
@given(a=gc_list_strategy)
def test_minimal_interval_invariant(a):
    """reduce_minimal produces strictly increasing starts and ends."""
    A = make_gc_list(a)
    if len(A) > 1:
        assert np.all(np.diff(A.starts) > 0)
        assert np.all(np.diff(A.ends) > 0)
    # idempotent
    again = reduce_minimal(A.starts, A.ends, A.values)
    assert again == A


def test_values_preserved_by_containment_and_merge():
    A = make_gc_list([(0, 1, 5.0), (10, 12, 7.0)])
    B = make_gc_list([(0, 4, 0.0)])
    node = gcl.ContainedIn(gcl.Term(A), gcl.Term(B))
    assert node.solutions() == [(0, 1, 5.0)]
    node = gcl.OneOf(gcl.Term(A), gcl.Term(B))
    sols = node.solutions()
    assert (0, 1, 5.0) in sols and (10, 12, 7.0) in sols


def test_phrase():
    # tokens: "to be or not to be" at addresses 0..5
    toks = {"to": [0, 4], "be": [1, 5], "or": [2], "not": [3]}
    lists = {w: make_gc_list([(p, p) for p in ps]) for w, ps in toks.items()}
    phrase = gcl.Phrase([gcl.Term(lists["to"]), gcl.Term(lists["be"])])
    assert [(p, q) for p, q, _ in phrase.solutions()] == [(0, 1), (4, 5)]
    phrase = gcl.Phrase([gcl.Term(lists["not"]), gcl.Term(lists["to"]), gcl.Term(lists["be"])])
    assert [(p, q) for p, q, _ in phrase.solutions()] == [(3, 5)]
    # τ_b from the right
    phrase = gcl.Phrase([gcl.Term(lists["to"]), gcl.Term(lists["be"])])
    assert phrase.tau_b(100)[:2] == (4, 5)
    assert phrase.tau_b(3)[:2] == (0, 1)


def test_paper_example_overlap():
    """'peanut butter △ jelly doughnut' sentence with two overlapping wits."""
    # Peanut(0) butter(1) on(2) a(3) jelly(4) doughnut(5) is(6) not(7) good(8)
    # as(9) a(10) peanut(11) butter(12) sandwich(13)
    pb = make_gc_list([(0, 1), (11, 12)])
    jd = make_gc_list([(4, 5)])
    node = gcl.BothOf(gcl.Term(pb), gcl.Term(jd))
    sols = [(p, q) for p, q, _ in node.solutions()]
    assert sols == [(0, 5), (4, 12)]  # overlapping, non-nesting witnesses

"""Chaos: concurrent writers/readers vs. a replica killer (``-m stress``).

N writer threads and M reader threads hammer a ``ShardedWarren(n_shards=3,
replicas=2)`` while commit-path hooks kill one replica per group mid-commit
(both before phase 1's ready — forcing quorum aborts — and between the
phases — forcing single-survivor publishes) and a resurrector thread
streams killed replicas back in.  Invariants:

  * no torn commits: every transaction is fully applied or fully aborted,
    including cross-shard annotate transactions;
  * readers never observe a partial transaction: a document's ``docid:``
    and ``chk:`` annotations (written in the same transaction) appear
    together or not at all;
  * after the dust settles, every replica pair is in address lockstep and
    ``search`` matches a single DynamicIndex rebuilt from exactly the
    committed documents.
"""

import random
import threading
import time

import numpy as np
import pytest

from repro.core import DynamicIndex, Warren, index_document, score_bm25
from repro.dist.shard_router import (QuorumError, ReplicaFailure,
                                     ShardedWarren)

VOCAB = ["school", "education", "student", "government", "law", "state",
         "stock", "money", "business", "vibration", "conductor", "wind"]

N_WRITERS = 3
N_READERS = 2
DOCS_PER_WRITER = 40


def _text(wid: int, i: int) -> str:
    rnd = random.Random(wid * 1000 + i)
    return " ".join(rnd.choice(VOCAB) for _ in range(4 + i % 5))


@pytest.mark.stress
def test_quorum_chaos_no_torn_commits():
    sw = ShardedWarren(n_shards=3, replicas=2)
    hook_lock = threading.Lock()
    counters = {"ready": 0, "mid": 0}

    def kill(group: int, replica: int) -> None:
        # never kill the last live replica: the group would be unrecoverable
        grp = sw.groups[group]
        if sum(grp.alive) >= 2 and grp.alive[replica]:
            grp.mark_failed(replica)

    def on_ready(group: int, replica: int) -> None:
        with hook_lock:
            counters["ready"] += 1
            n = counters["ready"]
        if n % 9 == 3:            # kill BEFORE ready → quorum abort path
            kill(group, replica)

    def mid_commit(warren: ShardedWarren, group: int) -> None:
        with hook_lock:
            counters["mid"] += 1
            n = counters["mid"]
        if n % 7 == 2:            # kill AFTER quorum → survivor publishes
            grp = sw.groups[group]
            kill(group, random.Random(n).choice(grp.live()))

    sw.hooks["on_ready"] = on_ready
    sw.hooks["mid_commit"] = mid_commit

    state_lock = threading.Lock()
    committed = {}                # docid -> text
    aborted = set()
    xtags = {}                    # feature -> expected annotation count
    torn = []                     # hard failures observed by any thread
    stop = threading.Event()

    def writer(wid: int) -> None:
        wc = sw.clone()
        for i in range(DOCS_PER_WRITER):
            docid = f"w{wid}-{i}"
            text = _text(wid, i)
            try:
                with wc:
                    wc.transaction()
                    lo, hi = index_document(wc, text, docid=docid)
                    wc.annotate("chk:" + docid, lo, hi, 1.0)
                    wc.commit()
                with state_lock:
                    committed[docid] = text
            except QuorumError:
                with state_lock:
                    aborted.add(docid)
            except ReplicaFailure:
                with state_lock:
                    aborted.add(docid)
            except RuntimeError as e:   # partial commits must never happen
                torn.append(f"writer {docid}: {e}")
                return
            if i % 6 == 5:              # cross-shard annotate transaction
                feature = f"xt{wid}-{i}:"
                try:
                    with wc:
                        docs = wc.annotations(":")
                        if len(docs) < 6:
                            continue
                        picks = [(int(docs.starts[j]), int(docs.ends[j]))
                                 for j in range(0, len(docs),
                                                max(len(docs) // 3, 1))][:3]
                        wc.transaction()
                        for p, q in picks:
                            wc.annotate(feature, p, q, 1.0)
                        wc.commit()
                    with state_lock:
                        xtags[feature] = len(picks)
                except (QuorumError, ReplicaFailure):
                    with state_lock:
                        xtags[feature] = 0
                except RuntimeError as e:
                    torn.append(f"writer {feature}: {e}")
                    return

    def reader(rid: int) -> None:
        wc = sw.clone()
        rnd = random.Random(rid)
        while not stop.is_set():
            with state_lock:
                sample = rnd.sample(sorted(committed),
                                    min(5, len(committed)))
            if not sample:
                time.sleep(0.005)
                continue
            try:
                with wc:
                    for docid in sample:
                        d = wc.annotations("docid:" + docid)
                        c = wc.annotations("chk:" + docid)
                        if len(d) != len(c):   # same-txn pair must co-appear
                            torn.append(
                                f"reader saw torn doc {docid}: "
                                f"{len(d)} docid vs {len(c)} chk")
                            return
            except ReplicaFailure as e:
                torn.append(f"reader failover exhausted: {e}")
                return

    def resurrector() -> None:
        while not stop.is_set():
            for g, grp in enumerate(sw.groups):
                for r in range(grp.n_replicas):
                    if not grp.alive[r]:
                        try:
                            sw.resurrect(g, r)
                        except ReplicaFailure:
                            pass
            time.sleep(0.002)

    writers = [threading.Thread(target=writer, args=(w,))
               for w in range(N_WRITERS)]
    readers = [threading.Thread(target=reader, args=(r,))
               for r in range(N_READERS)]
    res = threading.Thread(target=resurrector)
    for t in writers + readers + [res]:
        t.start()
    for t in writers:
        t.join(timeout=120)
    stop.set()
    for t in readers + [res]:
        t.join(timeout=30)

    sw.hooks.clear()
    for g, grp in enumerate(sw.groups):      # heal the cluster
        for r in range(grp.n_replicas):
            if not grp.alive[r]:
                sw.resurrect(g, r)

    assert torn == [], torn
    assert counters["ready"] > 0 and counters["mid"] > 0
    assert len(committed) > 20, "chaos killed almost every commit"
    assert aborted, "no quorum aborts were exercised"

    # 1. atomicity: committed docs fully present, aborted docs fully absent
    with sw:
        for docid in committed:
            assert len(sw.annotations("docid:" + docid)) == 1, docid
            assert len(sw.annotations("chk:" + docid)) == 1, docid
        for docid in aborted:
            assert len(sw.annotations("docid:" + docid)) == 0, docid
            assert len(sw.annotations("chk:" + docid)) == 0, docid
        for feature, n in xtags.items():     # cross-shard: all-or-nothing
            assert len(sw.annotations(feature)) in (0, n), feature

    # 2. replica lockstep after resurrection
    for grp in sw.groups:
        a, b = grp.replicas
        assert a._next_addr == b._next_addr
        assert a._next_seq == b._next_seq
        wa, wb = Warren(a), Warren(b)
        with wa, wb:
            for f in (":", "school", "money"):
                fv = sw.featurize(f)
                la, lb = wa.annotations(fv), wb.annotations(fv)
                assert np.array_equal(la.starts, lb.starts)
                assert np.array_equal(la.values, lb.values)

    # 3. equivalence with a single index over exactly the committed docs
    single = Warren(DynamicIndex())
    with single:
        single.transaction()
        for docid in sorted(committed):
            index_document(single, committed[docid], docid=docid)
        single.commit()
    with sw, single:
        for q in ("school education", "money business", "wind state"):
            ref = score_bm25(single, q, k=10)
            got = sw.search(q, k=10)
            np.testing.assert_allclose(sorted(s for _, s in got),
                                       sorted(s for _, s in ref), rtol=1e-9)


@pytest.mark.stress
def test_chaos_reader_failover_under_rolling_kills():
    """Readers keep answering while every replica is rolled through a
    kill/resurrect cycle; totals only ever grow with commits."""
    sw = ShardedWarren(n_shards=2, replicas=3)
    with sw:
        pass
    stop = threading.Event()
    errors = []
    totals = []

    def reader() -> None:
        # monotonic reads are a SESSION guarantee: each clone must never
        # un-see a commit, but two sessions may run at different snapshots
        wc = sw.clone()
        seen = 0
        while not stop.is_set():
            try:
                with wc:
                    n = len(wc.annotations(":"))
                if n < seen:
                    errors.append(
                        f"doc count went backwards: {n} after {seen}")
                    return
                seen = n
            except ReplicaFailure as e:
                errors.append(str(e))
                return
        totals.append(seen)

    def roller() -> None:
        rnd = random.Random(0)
        while not stop.is_set():
            g = rnd.randrange(sw.n_shards)
            grp = sw.groups[g]
            live = grp.live()
            if len(live) >= 2:
                victim = rnd.choice(live)
                grp.mark_failed(victim)
                time.sleep(0.002)
                sw.resurrect(g, victim)

    threads = [threading.Thread(target=reader) for _ in range(3)]
    threads.append(threading.Thread(target=roller))
    for t in threads:
        t.start()
    wc = sw.clone()
    for i in range(60):
        try:
            with wc:
                wc.transaction()
                index_document(wc, _text(9, i), docid=f"r{i}")
                wc.commit()
        except (QuorumError, ReplicaFailure):
            pass
    time.sleep(0.05)
    stop.set()
    for t in threads:
        t.join(timeout=30)
    assert errors == [], errors
    assert totals and max(totals) > 0

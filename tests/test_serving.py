"""Serving-path regression + async scatter-gather tests.

Covers the four serving bugfixes (batcher thread death on a handler
exception, posting-cap truncation by doc order instead of impact, stale KV
cache across ``LMServer.generate`` calls, eager materialization in the
sharded gather) and the ``repro.dist.parallel`` scatter-gather executor:
pool-based per-group fan-out must be result-identical to the sequential
loop — including under replica failover — and the native sharded
``RetrievalServer`` must match a single-index server bit-for-bit.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import (DynamicIndex, Warren, collection_stats,
                        index_document, ingest_documents, score_bm25)
from repro.data.synth import doc_generator
from repro.dist.parallel import ScatterGather, ScatterTimings
from repro.dist.shard_router import ShardedWarren
from repro.train.serve import BatcherConfig, MicroBatcher, RetrievalServer


# ------------------------------------------------------------------ #
# repro.dist.parallel: the executor itself
# ------------------------------------------------------------------ #
def test_scatter_gather_preserves_order():
    with ScatterGather(workers=4) as sg:
        def slow_identity(i):
            time.sleep(0.02 * (5 - i) / 5)     # later items finish first
            return i
        assert sg.map(slow_identity, range(5)) == [0, 1, 2, 3, 4]


def test_scatter_gather_runs_all_then_raises_first():
    ran = []
    lock = threading.Lock()

    def job(i):
        with lock:
            ran.append(i)
        if i in (1, 3):
            raise ValueError(f"job {i}")
        return i

    with ScatterGather(workers=2) as sg:
        with pytest.raises(ValueError, match="job 1"):
            sg.map(job, range(5))
    assert sorted(ran) == [0, 1, 2, 3, 4]      # no job was cancelled


def test_scatter_gather_closed_falls_back_to_sequential():
    sg = ScatterGather(workers=2)
    sg.close()
    assert sg.map(lambda i: i * i, range(4)) == [0, 1, 4, 9]


def test_scatter_timings_accumulate_and_reset():
    t = ScatterTimings()
    t.add(scatter=0.5, score=0.25, merge=0.25, queries=2)
    snap = t.snapshot()
    assert snap["queries"] == 2 and snap["scatter_s"] == 0.5
    assert "scatter" in t.summary() and "merge" in t.summary()
    t.reset()
    assert t.snapshot()["queries"] == 0


# ------------------------------------------------------------------ #
# bugfix: a handler exception must not kill the batcher thread
# ------------------------------------------------------------------ #
def test_microbatcher_survives_handler_exception():
    def handler(batch):
        if any(req == "poison" for req in batch):
            raise ValueError("bad batch")
        return [req.upper() for req in batch]

    mb = MicroBatcher(handler, BatcherConfig(max_batch=1, max_wait_ms=0.5))
    try:
        ok = mb.submit("first")
        assert ok.get(timeout=5) == "FIRST"
        # the poisoned batch fails ITS waiter with the handler's exception…
        poisoned = mb.submit("poison")
        with pytest.raises(ValueError, match="bad batch"):
            poisoned.get(timeout=5)
        # …and the loop is still alive for every later request
        for i in range(3):
            assert mb.submit(f"req{i}").get(timeout=5) == f"REQ{i}"
    finally:
        mb.close()


def test_microbatcher_close_fails_queued_waiters():
    release = threading.Event()

    def handler(batch):
        release.wait(5)
        return list(batch)

    mb = MicroBatcher(handler, BatcherConfig(max_batch=1, max_wait_ms=0.1))
    h1 = mb.submit("a")
    time.sleep(0.1)                   # the loop takes "a" into the handler
    h2 = mb.submit("b")               # still queued behind it
    closer = threading.Thread(target=mb.close)
    closer.start()
    time.sleep(0.05)
    release.set()
    closer.join()
    assert h1.get(timeout=5) == "a"   # the in-flight batch still completes
    with pytest.raises(RuntimeError, match="closed"):
        h2.get(timeout=5)             # queued waiter fails promptly
    with pytest.raises(RuntimeError, match="closed"):
        mb.submit("c").get(timeout=5)  # post-close submits fail fast


def test_microbatcher_result_count_mismatch_fails_batch():
    mb = MicroBatcher(lambda batch: [], BatcherConfig(max_batch=1))
    try:
        with pytest.raises(RuntimeError, match="results"):
            mb.submit("x").get(timeout=5)
    finally:
        mb.close()


# ------------------------------------------------------------------ #
# bugfix: the posting cap keeps top-impact postings, not doc-order ones
# ------------------------------------------------------------------ #
def test_posting_cap_keeps_high_impact_doc():
    warren = Warren(DynamicIndex())
    with warren:
        warren.transaction()
        for i in range(8):                      # tf=1 fodder, low impact
            index_document(warren, f"zzz filler{i} pad", docid=f"low{i}")
        # the BEST document for "zzz" sits PAST the posting cap in doc order
        index_document(warren, "zzz zzz zzz zzz", docid="best")
        warren.commit()
    with warren:
        oracle = score_bm25(warren, "zzz", k=3)
        docs = warren.annotations(":")
        ends = {int(s): int(e) for s, e in zip(docs.starts, docs.ends)}
        best_addr = oracle[0][0]
        assert "zzz zzz" in warren.translate(best_addr, ends[best_addr])
    server = RetrievalServer(warren, k=3, max_postings=4)
    try:
        res = server.query("zzz", timeout=30)
        assert res[0][0] == best_addr
        # device path scores in float32; the oracle in float64
        np.testing.assert_allclose(res[0][1], oracle[0][1], rtol=1e-6)
    finally:
        server.close()


# ------------------------------------------------------------------ #
# bugfix: LMServer must not decode against a previous call's KV cache
# ------------------------------------------------------------------ #
def test_lmserver_two_call_parity():
    import dataclasses

    import jax

    from repro.configs import get_arch
    from repro.train.serve import LMServer

    spec = get_arch("internlm2-1.8b")
    cfg = dataclasses.replace(spec.smoke_config, dtype="float32")
    params = spec.init_fn(cfg, jax.random.PRNGKey(0))
    server = LMServer(params, cfg, max_slots=2, max_len=16)
    prompts = [[5, 9, 2], [7, 4]]
    first = server.generate(prompts, max_new=4)
    second = server.generate(prompts, max_new=4)
    assert first == second
    assert all(len(o) == 4 for o in first)


# ------------------------------------------------------------------ #
# sharded serving: fixtures
# ------------------------------------------------------------------ #
def _ingest(warren, docs, batch=16):
    ingest_documents(warren, docs, batch=batch)


def _grouped_hits(warren, hits):
    """(rounded score, text) with equal-score ties grouped as frozensets —
    address layouts differ between sharded and single warrens by design."""
    docs = warren.annotations(":")
    ends = {int(s): int(e) for s, e in zip(docs.starts, docs.ends)}
    pairs = [(round(s, 9), warren.translate(d, ends[d])) for d, s in hits]
    groups, i = [], 0
    while i < len(pairs):
        j = i
        while j < len(pairs) and pairs[j][0] == pairs[i][0]:
            j += 1
        groups.append((pairs[i][0], frozenset(t for _, t in pairs[i:j])))
        i = j
    return groups


QUERIES = ["school education student", "government law state",
           "stock money business", "vibration conductor wind"]


@pytest.fixture(scope="module")
def serving_pair():
    corpus = list(doc_generator(7, 150, mean_len=40))
    sharded = ShardedWarren(n_shards=3, replicas=2, async_scatter=True)
    single = Warren(DynamicIndex())
    _ingest(sharded, corpus)
    _ingest(single, corpus)
    yield sharded, single
    sharded.close()


# ------------------------------------------------------------------ #
# async scatter == sequential scatter, failover preserved
# ------------------------------------------------------------------ #
def test_async_scatter_matches_sequential_reads(serving_pair):
    sharded, single = serving_pair
    assert sharded.async_scatter
    with sharded:
        async_res = {q: sharded.search(q, k=10) for q in QUERIES}
        async_docs = len(sharded.annotations(":"))
        async_stats = sharded.global_stats()
        async_gcl = sharded.search_gcl("school", limit=10_000)
    sharded.set_async_scatter(False)
    try:
        with sharded:
            for q in QUERIES:
                assert sharded.search(q, k=10) == async_res[q]
            assert len(sharded.annotations(":")) == async_docs
            seq_stats = sharded.global_stats()
            assert seq_stats.n_docs == async_stats.n_docs
            assert seq_stats.avgdl == async_stats.avgdl
            assert sharded.search_gcl("school", limit=10_000) == async_gcl
    finally:
        sharded.set_async_scatter(True)
    with single:
        for q in QUERIES:
            ref = _grouped_hits(single, score_bm25(single, q, k=10))
            with sharded:
                got = _grouped_hits(sharded, async_res[q])
            assert got == ref, q


def test_async_scatter_failover_inside_workers(serving_pair):
    sharded, single = serving_pair
    for g in range(sharded.n_shards):
        sharded.mark_failed(g, g % 2)
    try:
        with sharded, single:
            for q in QUERIES:
                assert _grouped_hits(sharded, sharded.search(q, k=10)) == \
                    _grouped_hits(single, score_bm25(single, q, k=10)), q
    finally:
        for g in range(sharded.n_shards):
            sharded.resurrect(g, g % 2)


def test_search_records_timing_breakdown(serving_pair):
    sharded, _ = serving_pair
    sharded.timings.reset()
    with sharded:
        sharded.search(QUERIES[0], k=10)
    snap = sharded.timings.snapshot()
    assert snap["queries"] == 1
    assert snap["scatter_s"] > 0 and snap["score_s"] > 0
    assert "ms/query" in sharded.timings.summary()


# ------------------------------------------------------------------ #
# bugfix: gather is lazy (islice) and exact at a tie on the k boundary
# ------------------------------------------------------------------ #
def test_sharded_search_tie_at_k_boundary():
    sharded = ShardedWarren(n_shards=3)
    single = Warren(DynamicIndex())
    docs = [(f"hi{i}", "school school education education") for i in range(3)]
    # 14 docs tied exactly (same tf, same dl, different filler terms so the
    # hash router spreads them over groups) — the k=10 boundary falls
    # INSIDE the tie group
    docs += [(f"tie{i}", f"school education filler{i}") for i in range(14)]
    docs += [(f"noise{i}", "stock money business") for i in range(6)]
    for docid, text in docs:                    # one txn per doc: spread out
        with sharded:
            sharded.transaction()
            index_document(sharded, text, docid=docid)
            sharded.commit()
        with single:
            single.transaction()
            index_document(single, text, docid=docid)
            single.commit()
    assert sum(len(g.replicas[0]._segments) > 0 for g in sharded.groups) > 1
    with sharded, single:
        got = sharded.search("school education", k=10)
        ref = score_bm25(single, "school education", k=10)
        assert len(got) == len(ref) == 10
        assert [round(s, 9) for _, s in got] == [round(s, 9) for _, s in ref]
        # ties truncated at the k boundary may keep different members
        # (addresses are striped, so tie order differs by design) — every
        # returned member must belong to the single-index tie class
        ref_all = score_bm25(single, "school education", k=25)
        classes = {}
        for score, texts in _grouped_hits(single, ref_all):
            classes[score] = texts
        for score, texts in _grouped_hits(sharded, got):
            assert texts <= classes[score], score


# ------------------------------------------------------------------ #
# acceptance: native sharded RetrievalServer == single-index server
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("max_postings", [4096, 8])
def test_sharded_server_matches_single_server(serving_pair, max_postings):
    """The micro-batched scatter/score/merge pipeline returns the same
    (score, text) ranking as the single-index device path — including with
    a tight posting cap, where the cap must bind to the GLOBAL top-impact
    postings, not per-group or doc-order ones."""
    sharded, single = serving_pair
    srv_sharded = RetrievalServer(sharded, k=10, max_postings=max_postings)
    srv_single = RetrievalServer(single, k=10, max_postings=max_postings)
    try:
        handles = [srv_sharded.batcher.submit(q) for q in QUERIES * 2]
        got = [h.get(timeout=60) for h in handles]
        ref = [srv_single.query(q, timeout=60) for q in QUERIES * 2]
        with sharded, single:
            for q, g_hits, r_hits in zip(QUERIES * 2, got, ref):
                assert _grouped_hits(sharded, g_hits) == \
                    _grouped_hits(single, r_hits), q
                np.testing.assert_allclose([s for _, s in g_hits],
                                           [s for _, s in r_hits], rtol=1e-9)
        assert srv_sharded.timings.snapshot()["queries"] >= len(QUERIES)
    finally:
        srv_sharded.close()
        srv_single.close()


def test_sharded_server_over_demoted_group(tmp_path):
    """The native scatter path reads demoted groups through their static
    run sets: results match a fully hot sharded warren."""
    corpus = list(doc_generator(11, 90, mean_len=30))
    sharded = ShardedWarren(n_shards=3, static_dir=str(tmp_path),
                            async_scatter=True)
    single = Warren(DynamicIndex())
    _ingest(sharded, corpus)
    _ingest(single, corpus)
    try:
        sharded.demote_group(1)
        server = RetrievalServer(sharded, k=10)
        oracle = RetrievalServer(single, k=10)
        try:
            hits = [(server.query(q, timeout=60), oracle.query(q, timeout=60))
                    for q in QUERIES[:2]]
            with sharded, single:
                for q, (got, ref) in zip(QUERIES[:2], hits):
                    assert _grouped_hits(sharded, got) == \
                        _grouped_hits(single, ref), q
        finally:
            server.close()
            oracle.close()
    finally:
        sharded.close()


def test_sharded_server_stats_refresh_after_commit(serving_pair):
    """The native path re-scatters stats per batch: documents committed
    after server construction are immediately retrievable."""
    sharded, _ = serving_pair
    server = RetrievalServer(sharded, k=5)
    try:
        with sharded:
            sharded.transaction()
            index_document(sharded, "xylophone quartz unique",
                           docid="fresh-doc")
            sharded.commit()
        res = server.query("xylophone quartz", timeout=30)
        assert len(res) == 1
        with sharded:
            docs = sharded.annotations(":")
            ends = {int(s): int(e) for s, e in zip(docs.starts, docs.ends)}
            assert "xylophone" in sharded.translate(res[0][0],
                                                    ends[res[0][0]])
        # clean up so the module-scoped corpus stays stable for other tests
        with sharded:
            sharded.transaction()
            sharded.erase(res[0][0], ends[res[0][0]])
            sharded.commit()
    finally:
        server.close()

"""Cache-invariant property matrix for the admission-controlled
:class:`repro.tiered.BlockCache`.

Four contracts, each a hard acceptance criterion of the
larger-than-memory serving issue:

* **exact byte accounting** — ``bytes == Σ len(entry)`` at every instant,
  including under concurrent readers hammering one cache from many
  threads (the accounting is all under one lock; this is the test that
  keeps it that way);
* **pinned blocks are never evicted** — extent assembly pins every block
  it straddles, so eviction racing a reader can never hand back freed
  payload;
* **admission earns its keep** — on a Zipf-with-scans trace the TinyLFU
  gate admits a hit rate at least as good as a plain byte-capacity LRU
  (the scan resistance the docstring promises);
* **the cache never changes answers** — reads through capacity 0 (pure
  pass-through), a tiny cache (constant thrash), and an unbounded cache
  are bit-identical.
"""

import threading
from collections import OrderedDict

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DynamicIndex, Warren, index_document, score_bm25
from repro.core.static import StaticIndex, write_static
from repro.tiered.cache import BlockCache

# ------------------------------------------------------------------ #
# exact accounting, sequential (hypothesis drives the op sequence)
# ------------------------------------------------------------------ #
_OPS = st.lists(
    st.tuples(
        st.sampled_from(["load", "load", "load", "get", "pin", "unpin",
                         "invalidate"]),
        st.integers(0, 11),          # key
        st.integers(1, 96),          # size (meaningful for "load" only;
    ),                               # sizes are a pure key function below)
    max_size=60)


@settings(max_examples=60, deadline=None)
@given(ops=_OPS, capacity=st.sampled_from([0, None, 16, 64, 256]))
def test_accounting_invariant_over_random_op_sequences(ops, capacity):
    cache = BlockCache(capacity_bytes=capacity, sketch_width=64)
    pinned = {}
    for op, key, _ in ops:
        size = 8 + 7 * key           # pure key function, like real blocks
        if op == "load":
            got = cache.get_or_load(key, lambda: bytes(size))
            assert got == bytes(size)
        elif op == "get":
            got = cache.get(key)
            assert got is None or isinstance(got, bytes)
        elif op == "pin":
            cache.pin(key)
            if key in cache._entries:
                pinned[key] = pinned.get(key, 0) + 1
        elif op == "unpin":
            cache.unpin(key)
            if pinned.get(key):
                pinned[key] -= 1
        else:
            cache.invalidate()
        cache.check_accounting()
        if capacity is not None:
            assert cache.bytes <= max(
                capacity, sum(e.nbytes for e in cache._entries.values()
                              if e.pins))
    # every key still pinned is still resident with its exact payload
    for key, n in pinned.items():
        if n > 0:
            assert key in cache._entries


# ------------------------------------------------------------------ #
# exact accounting under concurrent readers
# ------------------------------------------------------------------ #
def test_accounting_exact_under_concurrent_readers():
    cache = BlockCache(capacity_bytes=4096, sketch_width=256)
    n_threads, n_ops = 8, 400
    errors = []

    def reader(tid):
        rng = np.random.default_rng(tid)
        try:
            for i in range(n_ops):
                key = int(rng.zipf(1.3)) % 64
                size = 16 + (key * 7) % 80     # size is a pure key function
                got = cache.get_or_load(key, lambda s=size: bytes(s))
                if got != bytes(size):
                    errors.append((tid, i, key, "payload mismatch"))
                if i % 16 == 0:
                    cache.pin(key)
                    cache.unpin(key)
                if i % 64 == 0:
                    cache.check_accounting()
        except Exception as e:              # pragma: no cover
            errors.append((tid, repr(e)))

    threads = [threading.Thread(target=reader, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors[:5]
    cache.check_accounting()
    assert cache.bytes <= 4096
    s = cache.stats()
    assert s["hits"] + s["misses"] == n_threads * n_ops


# ------------------------------------------------------------------ #
# pinned entries survive arbitrary pressure
# ------------------------------------------------------------------ #
def test_pinned_blocks_are_never_evicted():
    cache = BlockCache(capacity_bytes=512, sketch_width=4096)
    payload = bytes(range(128))
    cache.get_or_load("hot", lambda: payload)
    cache.pin("hot")
    # strictly increasing challenger frequencies: every newcomer beats the
    # resident flood blocks, so admission keeps evicting — and would
    # happily evict "hot" too; pinning must not let it
    for k in range(64):
        for _ in range(2 * k + 2):
            cache.get(("flood", k))
        cache.get_or_load(("flood", k), lambda: bytes(100))
    assert cache.evictions > 0                # pressure was real
    assert cache.get("hot") == payload        # still resident, exact bytes
    cache.invalidate()                        # drop-everything also skips pins
    assert cache.get("hot") == payload
    cache.check_accounting()
    cache.unpin("hot")
    cache.invalidate()
    assert "hot" not in cache._entries        # unpinned -> droppable again
    cache.check_accounting()


def test_fully_pinned_cache_rejects_instead_of_evicting():
    cache = BlockCache(capacity_bytes=256, sketch_width=64)
    cache.get_or_load("a", lambda: bytes(200))
    cache.pin("a")
    before = cache.stats()["admit_rejects"]
    cache.get_or_load("b", lambda: bytes(200))   # cannot fit, "a" pinned
    assert cache.stats()["admit_rejects"] > before
    assert cache.get("a") == bytes(200)
    cache.check_accounting()


# ------------------------------------------------------------------ #
# TinyLFU admission beats plain LRU on a skewed trace with scans
# ------------------------------------------------------------------ #
class _PlainLRU:
    """Reference policy: byte-capacity LRU, no admission, no segments."""

    def __init__(self, capacity):
        self.capacity = capacity
        self._d = OrderedDict()
        self.hits = 0

    def access(self, key, size):
        if key in self._d:
            self.hits += 1
            self._d.move_to_end(key)
            return
        while self._d and sum(self._d.values()) + size > self.capacity:
            self._d.popitem(last=False)
        if size <= self.capacity:
            self._d[key] = size


def test_admission_hit_rate_beats_plain_lru_on_zipf_with_scans():
    rng = np.random.default_rng(7)
    block = 64
    capacity = 24 * block
    trace = []
    for i in range(6000):
        if i % 500 < 60:                       # periodic sequential scan
            trace.append(10_000 + (i % 500))
        else:
            trace.append(int(rng.zipf(1.2)) % 200)
    cache = BlockCache(capacity_bytes=capacity, sketch_width=4096)
    lru = _PlainLRU(capacity)
    for key in trace:
        cache.get_or_load(key, lambda: bytes(block))
        lru.access(key, block)
    cache.check_accounting()
    assert cache.stats()["admit_rejects"] > 0   # the gate actually engaged
    assert cache.stats()["hits"] >= lru.hits, (cache.stats(), lru.hits)


# ------------------------------------------------------------------ #
# reads are bit-identical at capacity 0 / tiny / unbounded
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def run_dir(tmp_path_factory):
    idx = DynamicIndex()
    w = Warren(idx)
    with w:
        w.transaction()
        for i in range(40):
            index_document(w, f"cache parity doc {i} shared fox words "
                              f"{'extra ' * (i % 5)}", docid=f"d{i}")
        w.commit()
    d = str(tmp_path_factory.mktemp("run") / "static")
    write_static(idx, d)
    return d


@pytest.mark.parametrize("capacity", [0, 3 * 4096, None],
                         ids=["passthrough", "tiny", "unbounded"])
def test_reads_bit_identical_across_capacity_modes(run_dir, capacity):
    ref = StaticIndex(run_dir, block_cache=BlockCache(capacity_bytes=None))
    si = StaticIndex(run_dir,
                     block_cache=BlockCache(capacity_bytes=capacity))
    try:
        for feature in (":", "fox", "shared", "docid:d7", "docid:d31"):
            a, b = ref.annotations(feature), si.annotations(feature)
            np.testing.assert_array_equal(a.starts, b.starts)
            np.testing.assert_array_equal(a.ends, b.ends)
            np.testing.assert_array_equal(a.values, b.values)
        docs = ref.annotations(":")
        for i in range(len(docs)):
            p, q = int(docs.starts[i]), int(docs.ends[i])
            assert ref.translate(p, q) == si.translate(p, q)
            assert ref.tokens(p, q) == si.tokens(p, q)
        got = score_bm25(si, "shared fox", k=10)
        want = score_bm25(ref, "shared fox", k=10)
        assert [g for g, _ in got] == [w_ for w_, _ in want]
        np.testing.assert_allclose([s for _, s in got],
                                   [s for _, s in want], rtol=0, atol=0)
    finally:
        ref.close()
        si.close()
